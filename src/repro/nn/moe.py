"""Mixture-of-Experts FFN with capacity-factor scatter dispatch + EP sharding.

Dispatch is scatter-based (positions via cumsum of one-hot), NOT the
O(T·E·C·d) one-hot matmul: cost is O(T·E) int ops for positions plus O(T·d)
scatter/gather — the MODEL_FLOPS/HLO_FLOPS roofline ratio stays honest.
Experts are sharded over the "model" axis (EP); XLA GSPMD inserts the
token all-to-all at the dispatch/combine boundaries.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .module import boxed_param, shard_activation


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    n_shared: int = 0  # shared (always-on) experts, DeepSeek/Llama4-style
    every: int = 1  # MoE in every k-th layer (1 = all layers)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # below this many (token, k) slots use dropless capacity (C = T*K):
    # decode batches must never drop tokens, and the buffer is tiny there.
    dropless_threshold: int = 4096


def swiglu_init(rng, d, d_ff, dtype=jnp.float32, expert_dim: int | None = None):
    r = jax.random.split(rng, 2)
    if expert_dim is None:
        return {
            "wi": {"kernel": boxed_param(r[0], (d, 2 * d_ff), ("embed", "mlp"), dtype)},
            "wo": {"kernel": boxed_param(r[1], (d_ff, d), ("mlp", "embed"), dtype)},
        }
    return {
        "wi": {"kernel": boxed_param(
            r[0], (expert_dim, d, 2 * d_ff), ("experts", "embed", None), dtype
        )},
        "wo": {"kernel": boxed_param(
            r[1], (expert_dim, d_ff, d), ("experts", None, "embed"), dtype
        )},
    }


def ffn_init(rng, d, d_ff, dtype=jnp.float32):
    return swiglu_init(rng, d, d_ff, dtype)


def ffn(params, x):
    gu = x @ params["wi"]["kernel"]
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    # rank-adaptive: [B,S,d_ff] from dense layers, [T,d_ff] from the MoE
    # shared-expert path
    axes = ("batch",) + ("act_seq",) * (h.ndim - 2) + ("act_model",)
    h = shard_activation(h, axes)
    return h @ params["wo"]["kernel"]


def moe_init(rng, d, m: MoESettings, dtype=jnp.float32):
    r = jax.random.split(rng, 3)
    p = {
        "router": {
            "kernel": boxed_param(
                r[0], (d, m.n_experts), ("embed", None), dtype
            )
        },
        "experts": swiglu_init(r[1], d, m.d_ff, dtype, expert_dim=m.n_experts),
    }
    if m.n_shared:
        p["shared"] = ffn_init(r[2], d, m.d_ff * m.n_shared, dtype)
    return p


def moe(params, m: MoESettings, x):
    """x: [B, S, d] -> [B, S, d] (+ aux loss stored via jax side output).

    Returns (out, aux_loss). aux_loss is the standard load-balancing loss
    (mean fraction · mean router prob per expert · E).
    """
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xt = x.reshape(T, d)
    logits = (xt @ params["router"]["kernel"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balancing loss (Switch) ---
    me = probs.mean(axis=0)  # [E]
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    aux = (me * ce).sum() * E * m.router_aux_weight

    # --- capacity dispatch ---
    if T * K <= m.dropless_threshold:
        C = T * K  # dropless (decode / tiny batches)
    else:
        C = max(int(m.capacity_factor * T * K / E), 1)
    e_flat = idx.reshape(T * K)  # [TK]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # [TK, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1  # running count per expert
    pos = jnp.take_along_axis(pos_in_e, e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    x_rep = jnp.repeat(xt, K, axis=0)  # [TK, d] token per slot
    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[e_flat, jnp.where(keep, pos, 0)].add(
        x_rep * keep[:, None].astype(xt.dtype),
        mode="drop",
    )
    buf = shard_activation(buf, ("act_model", None, None))

    # --- expert computation (batched over experts, EP-sharded) ---
    wi = params["experts"]["wi"]["kernel"]  # [E, d, 2ff]
    wo = params["experts"]["wo"]["kernel"]  # [E, ff, d]
    gu = jnp.einsum("ecd,edf->ecf", buf, wi)
    g, u = jnp.split(gu, 2, axis=-1)
    # expert activation stays in the compute dtype: an f32 silu intermediate
    # here gets stacked per scan group by XLA (22 GB/device on llama4)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)
    out_buf = shard_activation(out_buf, ("act_model", None, None))

    # --- combine ---
    gathered = out_buf[e_flat, jnp.where(keep, pos, 0)]  # [TK, d]
    gathered = gathered * (keep[:, None] * gates.reshape(T * K)[:, None]).astype(
        x.dtype
    )
    y = gathered.reshape(T, K, d).sum(axis=1)
    if "shared" in params:
        y = y + ffn(params["shared"], xt)
    return y.reshape(B, S, d), aux
