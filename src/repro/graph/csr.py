"""Graph storage structures.

Two device-side layouts and one host-side layout:

- ``CSRGraph`` (host, numpy): canonical compressed-sparse-row adjacency. Used by
  generators, the numpy oracle, and for conversion.
- ``EllGraph`` (device, jnp): padded fixed-width neighbor lists (ELL format).
  TPU-friendly: every row has ``max_deg`` slots, padding uses the out-of-bounds
  sentinel ``n_nodes`` so scatter ops drop it. This is the layout the IFE engine
  extends frontiers over.
- ``BinnedRevEll`` (device, jnp): degree-binned reverse-adjacency slabs for the
  bottom-up (pull) extension. Rows are permuted into pow2-bounded degree
  buckets and each bucket is its own ELL slab padded only to that bucket's
  width, so a pull scan costs ~``sum(in_deg)`` slots instead of the single
  padded slab's ``n × max_in_deg`` (EmptyHeaded-style per-row layout
  specialization). The (permutation, inverse) pair restores the original row
  order bit-identically.
- ``BlockAdjacency`` (device, jnp): 0/1 dense blocks of the adjacency matrix plus
  block coordinates — the block-sparse layout consumed by the ``msbfs_extend``
  Pallas kernel (MXU formulation of MS-BFS).

The paper's Kuzu implementation reads CSR through a disk buffer manager; on TPU the
partitioned adjacency is HBM-resident, and "amount of scans" becomes HBM bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Host-side CSR adjacency (out-edges)."""

    indptr: np.ndarray  # [n_nodes + 1] int64
    indices: np.ndarray  # [n_edges] int32, destination node ids
    weights: Optional[np.ndarray] = None  # [n_edges] float32 (optional)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indptr[-1])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def avg_degree(self) -> float:
        return self.n_edges / max(self.n_nodes, 1)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def reverse(self) -> "CSRGraph":
        """In-edge CSR (transpose)."""
        n = self.n_nodes
        src = np.repeat(np.arange(n, dtype=np.int32), self.degrees)
        order = np.argsort(self.indices, kind="stable")
        rindices = src[order]
        rindptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(rindptr, self.indices + 1, 1)
        rindptr = np.cumsum(rindptr)
        w = None if self.weights is None else self.weights[order]
        return CSRGraph(indptr=rindptr, indices=rindices, weights=w)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(
            np.arange(self.n_nodes, dtype=np.int32), self.degrees
        )
        return src, self.indices.astype(np.int32)

    def edge_keys(self) -> np.ndarray:
        """Sorted ``src * n_nodes + dst`` int64 keys, one per edge — the
        identity the dedup in ``csr_from_edges`` and the delta layer's
        edge-set arithmetic (``graph.delta``) both key on. Self-loops are
        ordinary keys; a deduped CSR's keys are strictly increasing.

        Supported range: ``n_nodes < 2**31``. Node ids are stored as int32
        throughout the operand layouts, and the int64 key arithmetic itself
        overflows near ``n_nodes ~ 2**31.5``; the int32 storage bound is hit
        first, so we raise there rather than silently wrap."""
        if self.n_nodes >= 2**31:
            raise ValueError(
                f"n_nodes={self.n_nodes} exceeds the int32 node-id range "
                "(< 2**31) that edge keys and operand layouts support"
            )
        src = np.repeat(
            np.arange(self.n_nodes, dtype=np.int64), self.degrees
        )
        return src * self.n_nodes + self.indices.astype(np.int64)


def csr_from_edges(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
) -> CSRGraph:
    """Build CSR from an edge list, sorting (and optionally deduplicating).

    The dedup is *stable keep-first* over the ``src * n_nodes + dst`` key
    (see ``CSRGraph.edge_keys``): among duplicate edges the one earliest
    in the input order survives, weights included. The mutable-graph path
    (``graph.delta.apply_delta_csr``) relies on this by concatenating
    surviving old edges ahead of inserts — re-inserting a live edge keeps
    the existing edge and its weight, exactly as a from-scratch build of
    the same concatenated list would.

    Supported range: ``n_nodes < 2**31``. The emitted ``indices`` are int32
    (every downstream operand layout stores node ids as int32), so larger
    graphs would silently wrap on the cast; we raise instead. The int64
    ``src * n_nodes + dst`` dedup key overflows slightly later (around
    ``n_nodes ~ 2**31.5``), so the int32 bound is the binding one."""
    if n_nodes >= 2**31:
        raise ValueError(
            f"n_nodes={n_nodes} exceeds the int32 node-id range (< 2**31); "
            "indices would silently wrap on the int32 cast"
        )
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    key = src * n_nodes + dst
    order = np.argsort(key, kind="stable")
    key, src, dst = key[order], src[order], dst[order]
    if weights is not None:
        weights = np.asarray(weights, dtype=np.float32)[order]
    if dedup and len(key):
        keep = np.concatenate([[True], key[1:] != key[:-1]])
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(
        indptr=indptr, indices=dst.astype(np.int32), weights=weights
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Device-side padded neighbor lists.

    ``indices[v, j]`` is the j'th out-neighbor of v, or ``n_nodes`` (an
    out-of-bounds sentinel) when ``j >= degree(v)``. Scatter updates use
    ``mode='drop'`` so sentinel writes vanish; gathers index a (n_nodes+1)-sized
    array whose last row is a neutral element.
    """

    indices: jax.Array  # [n_nodes, max_deg] int32
    degrees: jax.Array  # [n_nodes] int32
    weights: Optional[jax.Array] = None  # [n_nodes, max_deg] float32

    @property
    def n_nodes(self) -> int:
        return self.indices.shape[0]

    @property
    def max_deg(self) -> int:
        return self.indices.shape[1]

    @property
    def mask(self) -> jax.Array:
        return (
            jnp.arange(self.max_deg, dtype=jnp.int32)[None, :]
            < self.degrees[:, None]
        )

    @property
    def n_edges(self) -> jax.Array:
        return self.degrees.sum()


def _ell_slot_positions(
    indptr: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized (row, slot, csr_position) triples for every kept edge:
    slot j of row v maps to csr position indptr[v] + j, for j < min(deg, cap)."""
    degs = np.diff(indptr).astype(np.int64)
    kept = np.minimum(degs, cap)
    rows = np.repeat(np.arange(len(degs), dtype=np.int64), kept)
    total = int(kept.sum())
    slots = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(kept) - kept, kept
    )
    pos = indptr[:-1][rows] + slots
    return rows, slots, pos


def ell_from_csr(
    csr: CSRGraph, max_deg: Optional[int] = None, pad_to_multiple: int = 8
) -> EllGraph:
    """Convert CSR → ELL, truncating rows beyond ``max_deg`` if given.

    Fully vectorized (no per-node Python loop): host-side graph prep is
    O(n_edges) numpy index arithmetic, so setup no longer dominates for
    large graphs.

    A zero effective cap (``max_deg=0``, or an edgeless graph with
    ``max_deg=None``) yields a genuine zero-width ``[n, 0]`` slab — NOT a
    1-wide padded row. Every slot of a 1-wide slab would be scanned by
    every backend on every iteration for rows that own no edges, which
    breaks the binned-pull scanned-slot accounting (and the historical
    ``max_deg or 1`` coercion silently turned an explicit 0 into 8)."""
    n = csr.n_nodes
    degs = csr.degrees.astype(np.int32)
    if max_deg is None:
        cap = int(degs.max()) if n else 0
    else:
        cap = max(int(max_deg), 0)
    if cap > 0:
        cap = -(-cap // pad_to_multiple) * pad_to_multiple
    indices = np.full((n, cap), n, dtype=np.int32)  # sentinel = n
    rows, slots, pos = _ell_slot_positions(csr.indptr, cap)
    indices[rows, slots] = csr.indices[pos]
    w = None
    if csr.weights is not None:
        w = np.zeros((n, cap), dtype=np.float32)
        w[rows, slots] = csr.weights[pos]
    clipped = np.minimum(degs, cap)
    return EllGraph(
        indices=jnp.asarray(indices),
        degrees=jnp.asarray(clipped),
        weights=None if w is None else jnp.asarray(w),
    )


def truncate_csr(csr: CSRGraph, max_deg: Optional[int]) -> CSRGraph:
    """The *effective* graph after an ELL degree cap: first ``max_deg``
    out-edges per node. Reverse-ELL and block operands are derived from this
    so every extension backend scans the same edge set (bit-parity)."""
    if max_deg is None or (len(csr.degrees) == 0) or (
        int(csr.degrees.max()) <= max_deg
    ):
        return csr
    rows, _, pos = _ell_slot_positions(csr.indptr, int(max_deg))
    kept = np.minimum(csr.degrees, int(max_deg))
    indptr = np.zeros(csr.n_nodes + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(kept)
    return CSRGraph(
        indptr=indptr,
        indices=csr.indices[pos].astype(np.int32),
        weights=None if csr.weights is None else csr.weights[pos],
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinnedRevEll:
    """Degree-binned reverse-adjacency slabs (the pull-gather operand).

    Reverse rows are partitioned into degree buckets with pow2-bounded
    edges, refined so that every row's slab width is within
    ``max_overhead`` of its true in-degree; bucket ``b`` is a dense ELL
    slab ``slabs[b]: [K, rows_b, width_b]`` holding in-neighbor ids
    (sentinel = padded row count ⇒ out-of-range gathers fill with the
    neutral element). ``K`` is the graph shard count: shard ``k`` owns
    contiguous local rows ``[k·rows_local, (k+1)·rows_local)`` and bins
    them independently, but slab shapes are common across shards (counts
    padded to the per-bucket max) so the structure is SPMD under
    shard_map — leading axes shard over the policy's graph mesh axes.

    Row placement is carried by a per-shard permutation: concatenating
    the slabs row-major gives a ``[K, rows_binned]`` virtual vector of
    per-row gather results; ``perm[k, p]`` is the local row stored at
    binned position ``p`` (``rows_local`` for slab-padding rows) and
    ``inv[k, r]`` is the binned position of local row ``r`` — so
    ``cat[inv]`` restores the original row order bit-identically.

    Zero-in-degree rows (including rows emptied by degree truncation)
    live in a genuine **zero-width** slab: they cost nothing to scan.
    """

    slabs: tuple  # of jax.Array [K, rows_b, width_b] int32 per bucket
    perm: jax.Array  # [K, rows_binned] int32 (binned pos -> local row)
    inv: jax.Array  # [K, rows_local] int32 (local row -> binned pos)
    slab_weights: Optional[tuple] = None  # [K, rows_b, width_b] f32 each

    @property
    def n_slabs(self) -> int:
        return len(self.slabs)

    @property
    def rows_local(self) -> int:
        return self.inv.shape[-1]

    @property
    def widths(self) -> tuple:
        return tuple(int(s.shape[-1]) for s in self.slabs)

    @property
    def capacity_slots(self) -> int:
        """Total adjacency slots of one shard's full scan (the binned
        pull's worst-case per-iteration scan extent)."""
        return int(sum(s.shape[-2] * s.shape[-1] for s in self.slabs))

    def row_widths(self) -> np.ndarray:
        """[K, rows_local] host array: each local row's slab width — the
        slots a pull scan pays for that row (scanned-slot accounting)."""
        w = np.concatenate(
            [
                np.full((s.shape[-2],), s.shape[-1], np.int64)
                for s in self.slabs
            ]
        )
        return w[np.asarray(self.inv)]


def _degree_bucket_edges(
    degs: np.ndarray, max_overhead: float
) -> list[tuple[int, int]]:
    """Inclusive (lo, hi) degree ranges of the nonzero buckets.

    Pow2 bucket edges, greedily refined over the distinct degree values
    so every bucket satisfies ``hi <= max_overhead * lo`` — which bounds
    each row's padding (slab width / true degree) and therefore the whole
    structure's scan overhead by ``max_overhead``."""
    uniq = np.unique(degs[degs > 0])
    edges: list[tuple[int, int]] = []
    i = 0
    while i < len(uniq):
        lo = int(uniq[i])
        pow2_hi = 1 << (lo - 1).bit_length() if lo > 1 else 1
        limit = min(int(lo * max_overhead), pow2_hi) if lo > 1 else 1
        j = i
        while j + 1 < len(uniq) and int(uniq[j + 1]) <= limit:
            j += 1
        edges.append((lo, int(uniq[j])))
        i = j + 1
    return edges


def binned_rev_csr(
    csr: CSRGraph,
    n_pad: int,
    shards: int = 1,
    max_overhead: float = 1.1,
) -> BinnedRevEll:
    """Build the degree-binned reverse slabs of (the truncated) ``csr``.

    ``csr`` is the *forward* effective graph (see ``truncate_csr``) so the
    pull gather enumerates exactly the edge set every other backend scans;
    ``n_pad`` is the padded row count (divisible by ``shards``); rows
    ``>= csr.n_nodes`` are empty and land in the zero-width slab.
    Host-side, vectorized numpy; deterministic in its inputs.
    """
    assert n_pad % max(shards, 1) == 0, (n_pad, shards)
    rev = csr.reverse()
    n = rev.n_nodes
    rows_local = n_pad // shards
    degs = np.zeros(n_pad, np.int64)
    degs[:n] = rev.degrees
    nz_edges = _degree_bucket_edges(degs, max_overhead)
    # bucket 0 is always the zero-width slab (rows with no in-edges)
    bucket_of = np.zeros(n_pad, np.int64)
    widths = [0]
    for b, (lo, hi) in enumerate(nz_edges, start=1):
        bucket_of[(degs >= lo) & (degs <= hi)] = b
        widths.append(hi)
    n_buckets = len(widths)
    shard_of = np.arange(n_pad, dtype=np.int64) // rows_local
    local = np.arange(n_pad, dtype=np.int64) % rows_local

    # per-(shard, bucket) counts; slab row counts pad to the shard max
    counts = np.zeros((shards, n_buckets), np.int64)
    np.add.at(counts, (shard_of, bucket_of), 1)
    rows_b = counts.max(axis=0)
    starts = np.concatenate([[0], np.cumsum(rows_b)])[:-1]
    rows_binned = int(rows_b.sum())

    # stable slot assignment: rows of one (shard, bucket) keep ascending
    # local-row order — the permutation is deterministic
    order = np.lexsort((local, bucket_of, shard_of))
    o_shard, o_bucket, o_local = (
        shard_of[order], bucket_of[order], local[order]
    )
    key = o_shard * n_buckets + o_bucket
    run_start = np.concatenate([[0], np.cumsum(np.bincount(
        key.astype(np.int64), minlength=shards * n_buckets
    ))])[:-1]
    slot_in_bucket = np.arange(n_pad, dtype=np.int64) - run_start[key]
    pos = starts[o_bucket] + slot_in_bucket  # binned position per row

    perm = np.full((shards, rows_binned), rows_local, np.int32)
    perm[o_shard, pos] = o_local.astype(np.int32)
    inv = np.zeros((shards, rows_local), np.int32)
    inv[o_shard, o_local] = pos.astype(np.int32)

    has_w = rev.weights is not None
    slabs, slab_w = [], []
    for b in range(n_buckets):
        w = widths[b]
        slab = np.full((shards, int(rows_b[b]), w), n_pad, np.int32)
        wslab = (
            np.zeros((shards, int(rows_b[b]), w), np.float32)
            if has_w
            else None
        )
        if w > 0:
            sel = o_bucket == b  # rows of this bucket, slot order
            rows = order[sel]  # global row ids
            kept = degs[rows]
            flat = np.repeat(np.arange(len(rows)), kept)
            slots = np.arange(int(kept.sum()), dtype=np.int64) - np.repeat(
                np.cumsum(kept) - kept, kept
            )
            src = rev.indptr[rows][flat] + slots
            slab[o_shard[sel][flat], slot_in_bucket[sel][flat], slots] = (
                rev.indices[src]
            )
            if has_w:
                wslab[
                    o_shard[sel][flat], slot_in_bucket[sel][flat], slots
                ] = rev.weights[src]
        slabs.append(jnp.asarray(slab))
        if has_w:
            slab_w.append(jnp.asarray(wslab))
    return BinnedRevEll(
        slabs=tuple(slabs),
        perm=jnp.asarray(perm),
        inv=jnp.asarray(inv),
        slab_weights=tuple(slab_w) if has_w else None,
    )


@dataclasses.dataclass(frozen=True)
class BinnedPlan:
    """Shard-independent layout of the degree-binned reverse slabs.

    Everything that couples shards in ``binned_rev_csr`` — the bucket
    edges (derived from the *global* degree histogram), the common
    (max-over-shards) slab row counts, and the row→bucket assignment — is
    computed here in one O(n) pass, so a single shard's slabs can then be
    built from its local reverse adjacency alone (``binned_rev_shard``)
    and bitwise-match the corresponding ``[k:k+1]`` slice of the wholesale
    build. This is the streamed operand path's planning half: host peak
    memory per shard is the shard's own slab bytes, not the whole
    structure's (see docs/scale.md).
    """

    widths: tuple  # per-bucket slab width; widths[0] == 0
    rows_b: np.ndarray  # [n_buckets] common slab row counts
    bucket_of: np.ndarray  # [n_pad] bucket id per padded row
    degs: np.ndarray  # [n_pad] effective in-degree per padded row
    shards: int
    n_pad: int

    @property
    def rows_local(self) -> int:
        return self.n_pad // self.shards

    @property
    def rows_binned(self) -> int:
        return int(self.rows_b.sum())


def binned_plan(
    rev_degs: np.ndarray,
    n_pad: int,
    shards: int = 1,
    max_overhead: float = 1.1,
) -> BinnedPlan:
    """Global planning pass of ``binned_rev_csr`` (same bucketing, same
    counts arithmetic) without touching any edge data: ``rev_degs`` is the
    effective graph's in-degree histogram (``np.bincount(eff.indices)``)."""
    assert n_pad % max(shards, 1) == 0, (n_pad, shards)
    rows_local = n_pad // shards
    degs = np.zeros(n_pad, np.int64)
    degs[: len(rev_degs)] = rev_degs
    nz_edges = _degree_bucket_edges(degs, max_overhead)
    bucket_of = np.zeros(n_pad, np.int64)
    widths = [0]
    for b, (lo, hi) in enumerate(nz_edges, start=1):
        bucket_of[(degs >= lo) & (degs <= hi)] = b
        widths.append(hi)
    shard_of = np.arange(n_pad, dtype=np.int64) // rows_local
    counts = np.zeros((shards, len(widths)), np.int64)
    np.add.at(counts, (shard_of, bucket_of), 1)
    return BinnedPlan(
        widths=tuple(widths),
        rows_b=counts.max(axis=0),
        bucket_of=bucket_of,
        degs=degs,
        shards=shards,
        n_pad=n_pad,
    )


def binned_rev_shard(
    plan: BinnedPlan, k: int, rev_local: CSRGraph
) -> BinnedRevEll:
    """Shard ``k``'s slice of the wholesale ``binned_rev_csr`` structure
    (leading axis K=1), built from the shard's local reverse CSR alone
    (``partition.reverse_shard``). All leaves are host numpy so the caller
    controls device placement. Bitwise-identical to
    ``binned_rev_csr(...)``'s ``[k:k+1]`` slices by construction: the slot
    order within one (shard, bucket) is ascending local row — exactly what
    the wholesale lexsort produces — and the in-neighbor lists come from
    the same stable-by-destination edge order."""
    rl = plan.rows_local
    n_pad = plan.n_pad
    bucket_k = plan.bucket_of[k * rl : (k + 1) * rl]
    degs_k = plan.degs[k * rl : (k + 1) * rl]
    n_buckets = len(plan.widths)
    starts = np.cumsum(plan.rows_b) - plan.rows_b

    local = np.arange(rl, dtype=np.int64)
    order = np.argsort(bucket_k, kind="stable")  # (bucket, local) asc
    o_bucket, o_local = bucket_k[order], local[order]
    run_start = np.concatenate(
        [[0], np.cumsum(np.bincount(o_bucket, minlength=n_buckets))]
    )[:-1]
    slot_in_bucket = np.arange(rl, dtype=np.int64) - run_start[o_bucket]
    pos = starts[o_bucket] + slot_in_bucket

    perm = np.full((1, plan.rows_binned), rl, np.int32)
    perm[0, pos] = o_local.astype(np.int32)
    inv = np.zeros((1, rl), np.int32)
    inv[0, o_local] = pos.astype(np.int32)

    has_w = rev_local.weights is not None
    slabs, slab_w = [], []
    for b in range(n_buckets):
        w = plan.widths[b]
        rb = int(plan.rows_b[b])
        slab = np.full((1, rb, w), n_pad, np.int32)
        wslab = np.zeros((1, rb, w), np.float32) if has_w else None
        if w > 0:
            sel = o_bucket == b
            rows = o_local[sel]  # local row ids, slot order
            kept = degs_k[rows]
            flat = np.repeat(np.arange(len(rows)), kept)
            slots = np.arange(int(kept.sum()), dtype=np.int64) - np.repeat(
                np.cumsum(kept) - kept, kept
            )
            src = rev_local.indptr[rows][flat] + slots
            slab[0, slot_in_bucket[sel][flat], slots] = rev_local.indices[
                src
            ]
            if has_w:
                wslab[0, slot_in_bucket[sel][flat], slots] = (
                    rev_local.weights[src]
                )
        slabs.append(slab)
        if has_w:
            slab_w.append(wslab)
    return BinnedRevEll(
        slabs=tuple(slabs),
        perm=perm,
        inv=inv,
        slab_weights=tuple(slab_w) if has_w else None,
    )


def ell_shard(
    csr: CSRGraph, lo: int, hi: int, cap: int, sentinel: int
) -> tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Rows ``[lo, hi)`` of the padded ELL slab as host numpy
    ``(indices [rows, cap], degrees [rows], weights-or-None)`` — the
    streamed build's row-range counterpart of
    ``pad_ell(ell_from_csr(csr), ...)``. ``cap`` is the *global* padded
    row width and ``sentinel`` the padded node count ``n_pad`` (when
    ``n_pad == n_nodes`` the wholesale slab's sentinel is the same value,
    so the slices agree bitwise either way). Rows at or beyond
    ``csr.n_nodes`` are pad rows: all-sentinel, degree 0, zero weights."""
    n = csr.n_nodes
    rows = hi - lo
    lo_r, hi_r = min(lo, n), min(hi, n)
    indices = np.full((rows, cap), sentinel, np.int32)
    degs = np.zeros(rows, np.int32)
    w = (
        np.zeros((rows, cap), np.float32)
        if csr.weights is not None
        else None
    )
    if hi_r > lo_r and cap > 0:
        sub = csr.indptr[lo_r : hi_r + 1] - csr.indptr[lo_r]
        r, s, p = _ell_slot_positions(sub, cap)
        base = csr.indptr[lo_r]
        indices[r, s] = csr.indices[base + p]
        if w is not None:
            w[r, s] = csr.weights[base + p]
    if hi_r > lo_r:
        degs[: hi_r - lo_r] = np.minimum(
            csr.degrees[lo_r:hi_r], cap
        ).astype(np.int32)
    return indices, degs, w


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockAdjacency:
    """Block-sparse 0/1 adjacency: only blocks containing at least one edge are
    stored. ``blocks[b]`` is a dense ``[block, block]`` int8 tile;
    ``block_rows[b]``/``block_cols[b]`` give its (src-block, dst-block) coords.
    ``row_ptr`` groups the block list by src-block (CSR over blocks) so a kernel
    can iterate the nonzero blocks of one frontier stripe.
    """

    blocks: jax.Array  # [n_blocks, B, B] int8  (A[u, v] = 1 if edge u->v)
    block_rows: jax.Array  # [n_blocks] int32
    block_cols: jax.Array  # [n_blocks] int32
    row_ptr: jax.Array  # [n_row_blocks + 1] int32

    @property
    def block_size(self) -> int:
        return self.blocks.shape[1]

    @property
    def n_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_row_blocks(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def occupancy(self) -> float:
        """Fraction of the dense block grid that is materialized — the
        block-level sparsity economy (paper's 'reduced scans' analogue)."""
        g = self.n_row_blocks
        return self.n_blocks / float(g * g)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedBlocks:
    """Per-shard block-sparse 0/1 adjacency, stacked over graph shards.

    Shard k owns rows [k·rows_local, (k+1)·rows_local) of the padded graph;
    its nonzero ``[B, B]`` tiles have *local* source row-block ids
    (``block_rows``) and *global* destination col-block ids (``block_cols``).
    Shards are padded to one common block count with all-zero tiles whose col
    id is the out-of-range sentinel ``n_out // B`` (scatter ``mode='drop'``).
    Leading axis shards over the policy's graph mesh axes, so inside
    ``shard_map`` each device sees exactly its own ``[1, nb, B, B]`` slice.
    This is the operand of the ``block_mxu`` extension backend.
    """

    blocks: jax.Array  # [K, nb, B, B] int8
    block_rows: jax.Array  # [K, nb] int32 (local row-block ids)
    block_cols: jax.Array  # [K, nb] int32 (global col-block ids; pad = G)

    @property
    def block_size(self) -> int:
        return self.blocks.shape[2]


def sharded_blocks_from_csr(
    csr: CSRGraph, n_pad: int, shards: int, block: int = 128
) -> ShardedBlocks:
    """Build the stacked per-shard block adjacency (host-side, vectorized).

    ``n_pad`` must be divisible by ``shards * block``; pad rows/cols beyond
    ``csr.n_nodes`` are empty so they never materialize tiles.
    """
    assert n_pad % (shards * block) == 0, (n_pad, shards, block)
    rows_local = n_pad // shards
    rb = rows_local // block  # row blocks per shard
    g = n_pad // block  # global col blocks
    src, dst = csr.edge_list()
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    shard = src // rows_local
    br = (src % rows_local) // block
    bc = dst // block
    key = (shard * rb + br) * g + bc
    uniq, inv = np.unique(key, return_inverse=True)
    nb_tot = len(uniq)
    tiles = np.zeros((max(nb_tot, 1), block, block), dtype=np.int8)
    tiles[inv, src % block, dst % block] = 1
    u_shard = (uniq // (rb * g)).astype(np.int64)
    u_row = ((uniq // g) % rb).astype(np.int32)
    u_col = (uniq % g).astype(np.int32)
    counts = np.bincount(u_shard, minlength=shards) if nb_tot else np.zeros(
        shards, np.int64
    )
    nb = max(int(counts.max()) if nb_tot else 0, 1)
    out_blocks = np.zeros((shards, nb, block, block), dtype=np.int8)
    out_rows = np.zeros((shards, nb), dtype=np.int32)
    out_cols = np.full((shards, nb), g, dtype=np.int32)  # sentinel col
    if nb_tot:
        starts = np.cumsum(counts) - counts
        slot = np.arange(nb_tot) - starts[u_shard]
        out_blocks[u_shard, slot] = tiles[:nb_tot]
        out_rows[u_shard, slot] = u_row
        out_cols[u_shard, slot] = u_col
    return ShardedBlocks(
        blocks=jnp.asarray(out_blocks),
        block_rows=jnp.asarray(out_rows),
        block_cols=jnp.asarray(out_cols),
    )


def sharded_blocks_nb(
    csr: CSRGraph, n_pad: int, shards: int, block: int = 128
) -> int:
    """The common per-shard tile count ``nb`` of
    ``sharded_blocks_from_csr`` — the one global quantity a per-shard
    block build needs (shards pad their tile lists to the max count)."""
    assert n_pad % (shards * block) == 0, (n_pad, shards, block)
    rows_local = n_pad // shards
    rb = rows_local // block
    g = n_pad // block
    src, dst = csr.edge_list()
    src = src.astype(np.int64)
    key = ((src // rows_local) * rb + (src % rows_local) // block) * g + (
        dst.astype(np.int64) // block
    )
    uniq = np.unique(key)
    if not len(uniq):
        return 1
    counts = np.bincount(uniq // (rb * g), minlength=shards)
    return max(int(counts.max()), 1)


def sharded_blocks_shard(
    csr: CSRGraph,
    n_pad: int,
    shards: int,
    nb: int,
    f_lo: int,
    f_hi: int,
    block: int = 128,
) -> ShardedBlocks:
    """Fine shards ``[f_lo, f_hi)`` of the wholesale
    ``sharded_blocks_from_csr`` structure (leading axis ``f_hi - f_lo``),
    built from only those shards' edges. ``nb`` is the global common tile
    count (``sharded_blocks_nb``). Host numpy leaves. Bitwise-identical to
    the wholesale build's slices: a shard's edges are a contiguous CSR
    row-range slice, and ``np.unique`` over its keys reproduces the global
    sorted key order restricted to the shard (the shard id is the key's
    leading factor)."""
    rows_local = n_pad // shards
    rb = rows_local // block
    g = n_pad // block
    n = csr.n_nodes
    span = f_hi - f_lo
    lo = min(f_lo * rows_local, n)
    hi = min(f_hi * rows_local, n)
    e_lo, e_hi = int(csr.indptr[lo]), int(csr.indptr[hi])
    out_blocks = np.zeros((span, nb, block, block), np.int8)
    out_rows = np.zeros((span, nb), np.int32)
    out_cols = np.full((span, nb), g, np.int32)  # sentinel col
    if e_hi > e_lo:
        pos = np.arange(e_lo, e_hi, dtype=np.int64)
        src = np.searchsorted(csr.indptr, pos, side="right") - 1
        dst = csr.indices[e_lo:e_hi].astype(np.int64)
        shard = src // rows_local
        key = (shard * rb + (src % rows_local) // block) * g + dst // block
        uniq, inv = np.unique(key, return_inverse=True)
        tiles = np.zeros((len(uniq), block, block), np.int8)
        tiles[inv, src % block, dst % block] = 1
        u_shard = (uniq // (rb * g)).astype(np.int64) - f_lo
        counts = np.bincount(u_shard, minlength=span)
        starts = np.cumsum(counts) - counts
        slot = np.arange(len(uniq)) - starts[u_shard]
        out_blocks[u_shard, slot] = tiles
        out_rows[u_shard, slot] = ((uniq // g) % rb).astype(np.int32)
        out_cols[u_shard, slot] = (uniq % g).astype(np.int32)
    return ShardedBlocks(
        blocks=out_blocks, block_rows=out_rows, block_cols=out_cols
    )


def blocks_from_csr(csr: CSRGraph, block: int = 128) -> BlockAdjacency:
    """Build the block-sparse adjacency (host-side)."""
    n = csr.n_nodes
    g = -(-n // block)
    src, dst = csr.edge_list()
    br, bc = src // block, dst // block
    key = br.astype(np.int64) * g + bc
    uniq, inv = np.unique(key, return_inverse=True)
    nb = len(uniq)
    blocks = np.zeros((max(nb, 1), block, block), dtype=np.int8)
    lr = src % block
    lc = dst % block
    blocks[inv, lr, lc] = 1
    urows = (uniq // g).astype(np.int32)
    ucols = (uniq % g).astype(np.int32)
    row_ptr = np.zeros(g + 1, dtype=np.int32)
    np.add.at(row_ptr, urows + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    if nb == 0:
        urows = np.zeros(1, dtype=np.int32)
        ucols = np.zeros(1, dtype=np.int32)
    return BlockAdjacency(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(urows),
        block_cols=jnp.asarray(ucols),
        row_ptr=jnp.asarray(row_ptr),
    )
