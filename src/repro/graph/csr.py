"""Graph storage structures.

Two device-side layouts and one host-side layout:

- ``CSRGraph`` (host, numpy): canonical compressed-sparse-row adjacency. Used by
  generators, the numpy oracle, and for conversion.
- ``EllGraph`` (device, jnp): padded fixed-width neighbor lists (ELL format).
  TPU-friendly: every row has ``max_deg`` slots, padding uses the out-of-bounds
  sentinel ``n_nodes`` so scatter ops drop it. This is the layout the IFE engine
  extends frontiers over.
- ``BlockAdjacency`` (device, jnp): 0/1 dense blocks of the adjacency matrix plus
  block coordinates — the block-sparse layout consumed by the ``msbfs_extend``
  Pallas kernel (MXU formulation of MS-BFS).

The paper's Kuzu implementation reads CSR through a disk buffer manager; on TPU the
partitioned adjacency is HBM-resident, and "amount of scans" becomes HBM bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Host-side CSR adjacency (out-edges)."""

    indptr: np.ndarray  # [n_nodes + 1] int64
    indices: np.ndarray  # [n_edges] int32, destination node ids
    weights: Optional[np.ndarray] = None  # [n_edges] float32 (optional)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indptr[-1])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def avg_degree(self) -> float:
        return self.n_edges / max(self.n_nodes, 1)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]

    def reverse(self) -> "CSRGraph":
        """In-edge CSR (transpose)."""
        n = self.n_nodes
        src = np.repeat(np.arange(n, dtype=np.int32), self.degrees)
        order = np.argsort(self.indices, kind="stable")
        rindices = src[order]
        rindptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(rindptr, self.indices + 1, 1)
        rindptr = np.cumsum(rindptr)
        w = None if self.weights is None else self.weights[order]
        return CSRGraph(indptr=rindptr, indices=rindices, weights=w)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(
            np.arange(self.n_nodes, dtype=np.int32), self.degrees
        )
        return src, self.indices.astype(np.int32)


def csr_from_edges(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
) -> CSRGraph:
    """Build CSR from an edge list, sorting (and optionally deduplicating)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    key = src * n_nodes + dst
    order = np.argsort(key, kind="stable")
    key, src, dst = key[order], src[order], dst[order]
    if weights is not None:
        weights = np.asarray(weights, dtype=np.float32)[order]
    if dedup and len(key):
        keep = np.concatenate([[True], key[1:] != key[:-1]])
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(
        indptr=indptr, indices=dst.astype(np.int32), weights=weights
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Device-side padded neighbor lists.

    ``indices[v, j]`` is the j'th out-neighbor of v, or ``n_nodes`` (an
    out-of-bounds sentinel) when ``j >= degree(v)``. Scatter updates use
    ``mode='drop'`` so sentinel writes vanish; gathers index a (n_nodes+1)-sized
    array whose last row is a neutral element.
    """

    indices: jax.Array  # [n_nodes, max_deg] int32
    degrees: jax.Array  # [n_nodes] int32
    weights: Optional[jax.Array] = None  # [n_nodes, max_deg] float32

    @property
    def n_nodes(self) -> int:
        return self.indices.shape[0]

    @property
    def max_deg(self) -> int:
        return self.indices.shape[1]

    @property
    def mask(self) -> jax.Array:
        return (
            jnp.arange(self.max_deg, dtype=jnp.int32)[None, :]
            < self.degrees[:, None]
        )

    @property
    def n_edges(self) -> jax.Array:
        return self.degrees.sum()


def _ell_slot_positions(
    indptr: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized (row, slot, csr_position) triples for every kept edge:
    slot j of row v maps to csr position indptr[v] + j, for j < min(deg, cap)."""
    degs = np.diff(indptr).astype(np.int64)
    kept = np.minimum(degs, cap)
    rows = np.repeat(np.arange(len(degs), dtype=np.int64), kept)
    total = int(kept.sum())
    slots = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(kept) - kept, kept
    )
    pos = indptr[:-1][rows] + slots
    return rows, slots, pos


def ell_from_csr(
    csr: CSRGraph, max_deg: Optional[int] = None, pad_to_multiple: int = 8
) -> EllGraph:
    """Convert CSR → ELL, truncating rows beyond ``max_deg`` if given.

    Fully vectorized (no per-node Python loop): host-side graph prep is
    O(n_edges) numpy index arithmetic, so setup no longer dominates for
    large graphs."""
    n = csr.n_nodes
    degs = csr.degrees.astype(np.int32)
    cap = int(degs.max()) if max_deg is None and n else int(max_deg or 1)
    cap = max(cap, 1)
    cap = -(-cap // pad_to_multiple) * pad_to_multiple
    indices = np.full((n, cap), n, dtype=np.int32)  # sentinel = n
    rows, slots, pos = _ell_slot_positions(csr.indptr, cap)
    indices[rows, slots] = csr.indices[pos]
    w = None
    if csr.weights is not None:
        w = np.zeros((n, cap), dtype=np.float32)
        w[rows, slots] = csr.weights[pos]
    clipped = np.minimum(degs, cap)
    return EllGraph(
        indices=jnp.asarray(indices),
        degrees=jnp.asarray(clipped),
        weights=None if w is None else jnp.asarray(w),
    )


def truncate_csr(csr: CSRGraph, max_deg: Optional[int]) -> CSRGraph:
    """The *effective* graph after an ELL degree cap: first ``max_deg``
    out-edges per node. Reverse-ELL and block operands are derived from this
    so every extension backend scans the same edge set (bit-parity)."""
    if max_deg is None or (len(csr.degrees) == 0) or (
        int(csr.degrees.max()) <= max_deg
    ):
        return csr
    rows, _, pos = _ell_slot_positions(csr.indptr, int(max_deg))
    kept = np.minimum(csr.degrees, int(max_deg))
    indptr = np.zeros(csr.n_nodes + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(kept)
    return CSRGraph(
        indptr=indptr,
        indices=csr.indices[pos].astype(np.int32),
        weights=None if csr.weights is None else csr.weights[pos],
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockAdjacency:
    """Block-sparse 0/1 adjacency: only blocks containing at least one edge are
    stored. ``blocks[b]`` is a dense ``[block, block]`` int8 tile;
    ``block_rows[b]``/``block_cols[b]`` give its (src-block, dst-block) coords.
    ``row_ptr`` groups the block list by src-block (CSR over blocks) so a kernel
    can iterate the nonzero blocks of one frontier stripe.
    """

    blocks: jax.Array  # [n_blocks, B, B] int8  (A[u, v] = 1 if edge u->v)
    block_rows: jax.Array  # [n_blocks] int32
    block_cols: jax.Array  # [n_blocks] int32
    row_ptr: jax.Array  # [n_row_blocks + 1] int32

    @property
    def block_size(self) -> int:
        return self.blocks.shape[1]

    @property
    def n_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def n_row_blocks(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def occupancy(self) -> float:
        """Fraction of the dense block grid that is materialized — the
        block-level sparsity economy (paper's 'reduced scans' analogue)."""
        g = self.n_row_blocks
        return self.n_blocks / float(g * g)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedBlocks:
    """Per-shard block-sparse 0/1 adjacency, stacked over graph shards.

    Shard k owns rows [k·rows_local, (k+1)·rows_local) of the padded graph;
    its nonzero ``[B, B]`` tiles have *local* source row-block ids
    (``block_rows``) and *global* destination col-block ids (``block_cols``).
    Shards are padded to one common block count with all-zero tiles whose col
    id is the out-of-range sentinel ``n_out // B`` (scatter ``mode='drop'``).
    Leading axis shards over the policy's graph mesh axes, so inside
    ``shard_map`` each device sees exactly its own ``[1, nb, B, B]`` slice.
    This is the operand of the ``block_mxu`` extension backend.
    """

    blocks: jax.Array  # [K, nb, B, B] int8
    block_rows: jax.Array  # [K, nb] int32 (local row-block ids)
    block_cols: jax.Array  # [K, nb] int32 (global col-block ids; pad = G)

    @property
    def block_size(self) -> int:
        return self.blocks.shape[2]


def sharded_blocks_from_csr(
    csr: CSRGraph, n_pad: int, shards: int, block: int = 128
) -> ShardedBlocks:
    """Build the stacked per-shard block adjacency (host-side, vectorized).

    ``n_pad`` must be divisible by ``shards * block``; pad rows/cols beyond
    ``csr.n_nodes`` are empty so they never materialize tiles.
    """
    assert n_pad % (shards * block) == 0, (n_pad, shards, block)
    rows_local = n_pad // shards
    rb = rows_local // block  # row blocks per shard
    g = n_pad // block  # global col blocks
    src, dst = csr.edge_list()
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    shard = src // rows_local
    br = (src % rows_local) // block
    bc = dst // block
    key = (shard * rb + br) * g + bc
    uniq, inv = np.unique(key, return_inverse=True)
    nb_tot = len(uniq)
    tiles = np.zeros((max(nb_tot, 1), block, block), dtype=np.int8)
    tiles[inv, src % block, dst % block] = 1
    u_shard = (uniq // (rb * g)).astype(np.int64)
    u_row = ((uniq // g) % rb).astype(np.int32)
    u_col = (uniq % g).astype(np.int32)
    counts = np.bincount(u_shard, minlength=shards) if nb_tot else np.zeros(
        shards, np.int64
    )
    nb = max(int(counts.max()) if nb_tot else 0, 1)
    out_blocks = np.zeros((shards, nb, block, block), dtype=np.int8)
    out_rows = np.zeros((shards, nb), dtype=np.int32)
    out_cols = np.full((shards, nb), g, dtype=np.int32)  # sentinel col
    if nb_tot:
        starts = np.cumsum(counts) - counts
        slot = np.arange(nb_tot) - starts[u_shard]
        out_blocks[u_shard, slot] = tiles[:nb_tot]
        out_rows[u_shard, slot] = u_row
        out_cols[u_shard, slot] = u_col
    return ShardedBlocks(
        blocks=jnp.asarray(out_blocks),
        block_rows=jnp.asarray(out_rows),
        block_cols=jnp.asarray(out_cols),
    )


def blocks_from_csr(csr: CSRGraph, block: int = 128) -> BlockAdjacency:
    """Build the block-sparse adjacency (host-side)."""
    n = csr.n_nodes
    g = -(-n // block)
    src, dst = csr.edge_list()
    br, bc = src // block, dst // block
    key = br.astype(np.int64) * g + bc
    uniq, inv = np.unique(key, return_inverse=True)
    nb = len(uniq)
    blocks = np.zeros((max(nb, 1), block, block), dtype=np.int8)
    lr = src % block
    lc = dst % block
    blocks[inv, lr, lc] = 1
    urows = (uniq // g).astype(np.int32)
    ucols = (uniq % g).astype(np.int32)
    row_ptr = np.zeros(g + 1, dtype=np.int32)
    np.add.at(row_ptr, urows + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    if nb == 0:
        urows = np.zeros(1, dtype=np.int32)
        ucols = np.zeros(1, dtype=np.int32)
    return BlockAdjacency(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(urows),
        block_cols=jnp.asarray(ucols),
        row_ptr=jnp.asarray(row_ptr),
    )
