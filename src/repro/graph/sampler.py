"""Fanout neighbor sampler for minibatch GNN training (GraphSAGE-style).

This is the paper-technique integration point for the GNN cells
(DESIGN.md §4): multi-hop neighbor sampling IS bounded frontier expansion —
each hop extends the frontier of sampled nodes through the same ELL adjacency
the IFE engine scans, with a fanout cap instead of a visited filter. The
sampled tree is returned as a flat subgraph (edge lists with local indices)
so every GNN arch's edge-list ``apply`` runs unchanged on minibatch cells.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .csr import EllGraph


class SampledSubgraph(NamedTuple):
    nodes: jax.Array  # [n_sampled] global node ids (with repetition)
    edge_src: jax.Array  # [n_edges] local index into nodes (child)
    edge_dst: jax.Array  # [n_edges] local index into nodes (parent)
    seed_count: int  # first seed_count entries of nodes are the seeds


def sample_hop(
    g: EllGraph, frontier_nodes: jax.Array, fanout: int, rng
) -> jax.Array:
    """Sample ``fanout`` neighbors (with replacement) per frontier node.

    Returns [n_frontier, fanout] global ids. Zero-degree nodes self-loop
    (standard GraphSAGE padding). This is the sampled analogue of the IFE
    engine's ell frontier extension (same gather layout)."""
    n = frontier_nodes.shape[0]
    degs = jnp.take(g.degrees, frontier_nodes, axis=0)  # [n]
    slots = jax.random.randint(rng, (n, fanout), 0, 1 << 30)
    slots = slots % jnp.maximum(degs, 1)[:, None]
    rows = jnp.take(g.indices, frontier_nodes, axis=0)  # [n, max_deg]
    sampled = jnp.take_along_axis(rows, slots, axis=1)
    # zero-degree: self-loop
    return jnp.where(
        degs[:, None] > 0, sampled, frontier_nodes[:, None]
    ).astype(jnp.int32)


@partial(jax.jit, static_argnames=("fanouts",))
def sample_subgraph(
    g: EllGraph, seeds: jax.Array, fanouts: tuple, rng
) -> SampledSubgraph:
    """Layered fanout sampling: seeds [B] + fanouts (f1, f2, ...) ->
    flat subgraph with child->parent edges (messages flow toward seeds)."""
    layers = [seeds.astype(jnp.int32)]
    offsets = [0]
    total = seeds.shape[0]
    rngs = jax.random.split(rng, len(fanouts))
    for h, f in enumerate(fanouts):
        cur = layers[-1]
        sampled = sample_hop(g, cur, f, rngs[h])  # [n_cur, f]
        layers.append(sampled.reshape(-1))
        offsets.append(total)
        total += cur.shape[0] * f
    nodes = jnp.concatenate(layers)
    srcs, dsts = [], []
    for h, f in enumerate(fanouts):
        n_parent = layers[h].shape[0]
        parent_local = jnp.arange(n_parent, dtype=jnp.int32) + offsets[h]
        child_local = (
            jnp.arange(n_parent * f, dtype=jnp.int32) + offsets[h + 1]
        )
        srcs.append(child_local)
        dsts.append(jnp.repeat(parent_local, f))
    return SampledSubgraph(
        nodes=nodes,
        edge_src=jnp.concatenate(srcs),
        edge_dst=jnp.concatenate(dsts),
        seed_count=seeds.shape[0],
    )
