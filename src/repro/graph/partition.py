"""Graph partitioning for morsel policies.

Frontier morsels map to contiguous node-range partitions of the ELL adjacency
(paper §4.1: "obtaining frontier morsels ... returns back a range of integer
node IDs"). ``pad_ell`` pads the row count so it divides evenly across the
graph mesh axes; padded rows have degree 0 and the out-of-bounds sentinel, so
they are inert.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .csr import EllGraph


def padded_n(n_nodes: int, shards: int, block: int = 8) -> int:
    unit = shards * block
    return -(-n_nodes // unit) * unit


def pad_ell(g: EllGraph, shards: int, block: int = 8) -> EllGraph:
    """Pad ELL rows to a multiple of shards*block. Sentinel stays at the
    ORIGINAL n_nodes: scatters into the padded [n_pad] arrays treat original
    sentinel ids as real (but inert, degree-0) rows, which is harmless, and
    original ids never collide with pad rows... wait — sentinel == n_nodes
    lands on the first PAD row. Remap sentinel to n_pad so it stays
    out-of-bounds for [n_pad]-sized scatters."""
    n = g.n_nodes
    n_pad = padded_n(n, shards, block)
    if n_pad == n:
        return g
    sentinel_old, sentinel_new = n, n_pad
    idx = jnp.where(g.indices == sentinel_old, sentinel_new, g.indices)
    pad_rows = jnp.full((n_pad - n, g.max_deg), sentinel_new, dtype=idx.dtype)
    idx = jnp.concatenate([idx, pad_rows], axis=0)
    degs = jnp.concatenate(
        [g.degrees, jnp.zeros((n_pad - n,), g.degrees.dtype)]
    )
    w = None
    if g.weights is not None:
        w = jnp.concatenate(
            [g.weights, jnp.zeros((n_pad - n, g.max_deg), g.weights.dtype)]
        )
    return EllGraph(indices=idx, degrees=degs, weights=w)


def partition_bounds(n_pad: int, shards: int) -> np.ndarray:
    """Row offsets of each shard: [shards + 1]."""
    per = n_pad // shards
    return np.arange(shards + 1, dtype=np.int64) * per


def slab_edges(
    src: np.ndarray, dst: np.ndarray, n_nodes: int, k_slabs: int
) -> tuple[np.ndarray, np.ndarray]:
    """Destination-aligned edge slabs (models/gnn/common.set_edge_slabs):
    bucket edges by dst node range, pad every bucket to the max bucket size
    (pad edges: src=0, dst=n_nodes — dropped by segment reduces), return the
    flat concatenated (src, dst) arrays of length k_slabs × max_bucket.

    Skewed graphs pad up to the hottest slab; production loaders would
    rebalance slab boundaries by edge count instead of node count."""
    assert n_nodes % k_slabs == 0, (n_nodes, k_slabs)
    nl = n_nodes // k_slabs
    slab_of = np.minimum(dst // nl, k_slabs - 1)
    order = np.argsort(slab_of, kind="stable")
    src, dst, slab_of = src[order], dst[order], slab_of[order]
    counts = np.bincount(slab_of, minlength=k_slabs)
    width = max(int(counts.max()), 1)
    out_src = np.zeros((k_slabs, width), np.int32)
    out_dst = np.full((k_slabs, width), n_nodes, np.int32)
    start = 0
    for k in range(k_slabs):
        c = int(counts[k])
        out_src[k, :c] = src[start : start + c]
        out_dst[k, :c] = dst[start : start + c]
        start += c
    return out_src.reshape(-1), out_dst.reshape(-1)
