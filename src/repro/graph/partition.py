"""Graph partitioning for morsel policies.

Frontier morsels map to contiguous node-range partitions of the ELL adjacency
(paper §4.1: "obtaining frontier morsels ... returns back a range of integer
node IDs"). ``pad_ell`` pads the row count so it divides evenly across the
graph mesh axes; padded rows have degree 0 and the out-of-bounds sentinel, so
they are inert. ``reverse_shard`` is the streamed-build primitive: one
shard's rows of the transpose without materializing the whole reverse graph
(see docs/scale.md).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph, EllGraph


def padded_n(n_nodes: int, shards: int, block: int = 8) -> int:
    unit = shards * block
    return -(-n_nodes // unit) * unit


def pad_ell(g: EllGraph, shards: int, block: int = 8) -> EllGraph:
    """Pad ELL rows to a multiple of ``shards * block``.

    Sentinel-remap contract: the unpadded slab marks empty slots with the
    out-of-range id ``n_nodes``, but after padding, row ``n_nodes`` is a
    real (inert, degree-0) pad row — a scatter into a ``[n_pad]`` array
    would land on it instead of being dropped. So every ``n_nodes``
    sentinel is remapped to ``n_pad``, which is out of bounds for all
    ``[n_pad]``-sized scatters/gathers; pad rows are all-sentinel with
    degree 0 and zero weights. When no padding is needed the slab is
    returned unchanged (``n_pad == n_nodes``, so the sentinel already sits
    out of range)."""
    n = g.n_nodes
    n_pad = padded_n(n, shards, block)
    if n_pad == n:
        return g
    sentinel_old, sentinel_new = n, n_pad
    idx = jnp.where(g.indices == sentinel_old, sentinel_new, g.indices)
    pad_rows = jnp.full((n_pad - n, g.max_deg), sentinel_new, dtype=idx.dtype)
    idx = jnp.concatenate([idx, pad_rows], axis=0)
    degs = jnp.concatenate(
        [g.degrees, jnp.zeros((n_pad - n,), g.degrees.dtype)]
    )
    w = None
    if g.weights is not None:
        w = jnp.concatenate(
            [g.weights, jnp.zeros((n_pad - n, g.max_deg), g.weights.dtype)]
        )
    return EllGraph(indices=idx, degrees=degs, weights=w)


def partition_bounds(n_pad: int, shards: int) -> np.ndarray:
    """Row offsets of each shard: [shards + 1]."""
    per = n_pad // shards
    return np.arange(shards + 1, dtype=np.int64) * per


def reverse_shard(csr: CSRGraph, lo: int, hi: int) -> CSRGraph:
    """Rows ``[lo, hi)`` of ``csr.reverse()`` without materializing the
    full transpose — the streamed operand build's per-shard edge cut.

    Selects the edges whose destination lands in the range (ascending
    original edge order) and stable-sorts them by destination. A stable
    argsort restricted to a contiguous key range equals the stable sort of
    the selection, so the local in-neighbor lists are bitwise-identical to
    the corresponding rows of the wholesale transpose. ``hi`` may exceed
    ``csr.n_nodes`` (padded rows): the extra rows are empty. Returns a
    CSR with ``hi - lo`` rows whose ``indices`` are *global* source ids.
    """
    dst = csr.indices
    sel = np.flatnonzero((dst >= lo) & (dst < hi))
    # source id of each selected edge: its row in the forward CSR
    src = (
        np.searchsorted(csr.indptr, sel, side="right").astype(np.int64) - 1
    )
    d = dst[sel].astype(np.int64) - lo
    order = np.argsort(d, kind="stable")
    rindptr = np.zeros(hi - lo + 1, dtype=np.int64)
    rindptr[1:] = np.cumsum(np.bincount(d, minlength=hi - lo))
    w = None if csr.weights is None else csr.weights[sel][order]
    return CSRGraph(
        indptr=rindptr,
        indices=src[order].astype(np.int32),
        weights=w,
    )


def slab_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    k_slabs: int,
    balance: str = "nodes",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Destination-aligned edge slabs (models/gnn/common.set_edge_slabs):
    bucket edges by dst node range, pad every bucket to the max bucket size
    (pad edges: src=0, dst=n_nodes — dropped by segment reduces), return the
    flat concatenated (src, dst) arrays of length k_slabs × max_bucket plus
    the ``[k_slabs + 1]`` node boundaries of the slabs.

    ``balance="nodes"`` uses uniform node ranges (slab k owns nodes
    ``[k·n/K, (k+1)·n/K)``); ``balance="edges"`` instead places the
    boundaries on the in-degree cumsum so every slab holds ≈ E/K edges —
    skewed graphs no longer pad every bucket up to the hottest slab, which
    is also what keeps per-partition slab builds bounded. The fill is fully
    vectorized (no per-slab Python copy loop)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    if balance == "nodes":
        assert n_nodes % k_slabs == 0, (n_nodes, k_slabs)
        nl = n_nodes // k_slabs
        bounds = np.arange(k_slabs + 1, dtype=np.int64) * nl
    elif balance == "edges":
        indeg = np.bincount(dst, minlength=n_nodes)
        cum = np.concatenate([[0], np.cumsum(indeg)])  # [n_nodes + 1]
        targets = np.arange(1, k_slabs) * (len(dst) / k_slabs)
        cuts = np.searchsorted(cum, targets, side="left")
        bounds = np.concatenate(
            [[0], cuts, [n_nodes]]
        ).astype(np.int64)
    else:
        raise ValueError(balance)
    slab_of = np.clip(
        np.searchsorted(bounds, dst, side="right") - 1, 0, k_slabs - 1
    )
    order = np.argsort(slab_of, kind="stable")
    src, dst, slab_of = src[order], dst[order], slab_of[order]
    counts = np.bincount(slab_of, minlength=k_slabs)
    width = max(int(counts.max()), 1)
    starts = np.cumsum(counts) - counts
    pos = np.arange(len(src), dtype=np.int64) - starts[slab_of]
    out_src = np.zeros((k_slabs, width), np.int32)
    out_dst = np.full((k_slabs, width), n_nodes, np.int32)
    out_src[slab_of, pos] = src
    out_dst[slab_of, pos] = dst
    return out_src.reshape(-1), out_dst.reshape(-1), bounds
