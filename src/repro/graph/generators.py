"""Deterministic synthetic graph generators (host-side, numpy).

The paper evaluates on LDBC100, LiveJournal, Spotify, and Graph500-28
(20M–4.2B edges). This container is CPU-only, so benchmarks use *proxies* with
matched degree structure at reduced scale; the full-scale shapes appear only in
the dry-run (ShapeDtypeStructs, no allocation).

All generators are deterministic in (shape, seed).
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, csr_from_edges


def erdos_renyi(
    n_nodes: int, avg_degree: float, seed: int = 0, symmetric: bool = True
) -> CSRGraph:
    """G(n, m) with m = n*avg_degree directed edges (paper Fig 13 family)."""
    rng = np.random.default_rng(seed)
    m = int(n_nodes * avg_degree)
    src = rng.integers(0, n_nodes, size=m, dtype=np.int64)
    dst = rng.integers(0, n_nodes, size=m, dtype=np.int64)
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return csr_from_edges(n_nodes, src, dst)


def rmat(
    scale: int,
    edge_factor: int = 16,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    symmetric: bool = True,
) -> CSRGraph:
    """RMAT generator — Graph500 proxy (Graph500 uses a=.57 b=c=.19 d=.05)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities: (a, b, c, d)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(m)
        p_right = np.where(src_bit == 0, b / (a + b), (1 - a - b - c) / max(c + (1 - a - b - c), 1e-9))
        dst_bit = (r2 < p_right).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return csr_from_edges(n, src, dst)


def powerlaw(
    n_nodes: int,
    avg_degree: float,
    alpha: float = 2.1,
    seed: int = 0,
    symmetric: bool = True,
) -> CSRGraph:
    """Power-law out-degrees via Zipf-distributed endpoints (social-network
    proxy: LDBC/LiveJournal-like heavy-tail degree mix)."""
    rng = np.random.default_rng(seed)
    m = int(n_nodes * avg_degree)
    # Heavy-tailed endpoint popularity.
    ranks = np.arange(1, n_nodes + 1, dtype=np.float64)
    probs = ranks ** (-alpha / 2.0)
    probs /= probs.sum()
    perm = rng.permutation(n_nodes)
    src = perm[rng.choice(n_nodes, size=m, p=probs)]
    dst = perm[rng.choice(n_nodes, size=m, p=probs)]
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return csr_from_edges(n_nodes, src, dst)


# ---- paper-dataset proxies (reduced scale, matched avg degree) -------------

def ldbc_proxy(scale: float = 1.0, seed: int = 0) -> CSRGraph:
    """LDBC100: 448K nodes, 19.9M edges, avg degree 44."""
    n = max(int(4486 * scale), 64)
    return powerlaw(n, avg_degree=22.0, alpha=1.8, seed=seed)  # sym -> ~44


def lj_proxy(scale: float = 1.0, seed: int = 1) -> CSRGraph:
    """LiveJournal: 4.8M nodes, 69M edges, avg degree 14."""
    n = max(int(48476 * scale), 64)
    return powerlaw(n, avg_degree=7.0, alpha=2.1, seed=seed)  # sym -> ~14


def spotify_proxy(scale: float = 1.0, seed: int = 2) -> CSRGraph:
    """Spotify: 3.6M nodes, 1.9B edges, avg degree 535 (the dense outlier that
    drives the paper's cache-locality findings)."""
    n = max(int(3604 * scale), 256)
    return erdos_renyi(n, avg_degree=267.0, seed=seed)  # sym -> ~534


def graph500_proxy(scale_log2: int = 12, seed: int = 3) -> CSRGraph:
    """Graph500-28: RMAT, avg degree ~35. Reduced scale keeps structure."""
    return rmat(scale_log2, edge_factor=17, seed=seed)


PAPER_DATASETS = {
    "ldbc": ldbc_proxy,
    "lj": lj_proxy,
    "spotify": spotify_proxy,
    "graph500": lambda scale=1.0, seed=3: graph500_proxy(12, seed=seed),
}

#: degree-structure family of each proxy — the key the fitted
#: direction-threshold table (core.policies.DirectionThresholds) is looked
#: up by; keep in sync with PAPER_DATASETS when adding datasets
PAPER_DATASET_FAMILIES = {
    "ldbc": "powerlaw",
    "lj": "powerlaw",
    "spotify": "er",
    "graph500": "powerlaw",  # RMAT: heavy-tail, closest to the powerlaw fit
}


def pick_sources(
    csr: CSRGraph, n_sources: int, seed: int = 0, min_levels: int = 3
) -> np.ndarray:
    """Random sources that can sustain >= min_levels of IFE (paper §5.1).

    Uses a cheap numpy BFS depth probe per candidate.
    """
    rng = np.random.default_rng(seed)
    out: list[int] = []
    tried = set()
    # dense graphs (e.g. the Spotify proxy, diameter ~2) may have NO node
    # sustaining min_levels — cap the search by the node count and fall
    # back to accepting candidates rather than spinning
    budget = min(csr.n_nodes, 50 * n_sources + 1000)
    while len(out) < n_sources:
        cand = int(rng.integers(0, csr.n_nodes))
        if cand in tried and len(tried) < csr.n_nodes:
            continue
        tried.add(cand)
        if len(tried) >= budget or _bfs_depth_at_least(
            csr, cand, min_levels
        ):
            out.append(cand)
    return np.asarray(out[:n_sources], dtype=np.int32)


def _bfs_depth_at_least(csr: CSRGraph, src: int, depth: int) -> bool:
    seen = np.zeros(csr.n_nodes, dtype=bool)
    seen[src] = True
    frontier = np.asarray([src], dtype=np.int64)
    indptr, indices = csr.indptr, csr.indices
    for _ in range(depth):
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return False
        base = np.repeat(starts, counts)
        offs = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        nbrs = indices[base + offs]
        new = np.unique(nbrs[~seen[nbrs]])
        if new.size == 0:
            return False
        seen[new] = True
        frontier = new
    return True
