"""Edge deltas and incremental operand folding for mutable graphs.

A ``GraphDelta`` is one batch of edge edits (deletes applied first, then
inserts) against a host ``CSRGraph``. Two consumers:

- ``apply_delta_csr(csr, delta)`` — the *semantic* update: rebuilds the
  host CSR from the surviving + inserted edge list through the one shared
  ``csr_from_edges`` path (stable keep-first dedup), so the updated graph
  is edge-for-edge identical to building from scratch. This is the oracle
  every fold below must match.
- ``diff_effective`` + ``fold_operands`` — the *incremental* update:
  given the old and new effective (degree-truncated) graphs, compute
  exactly which padded rows / edge keys changed and rewrite only those in
  a writable host mirror of the device operand bundle. Structures keep
  their shapes whenever the existing slabs can absorb the change
  (re-binning moves rows between existing degree buckets through the
  perm/inverse contract, preserving the ``width/deg <= max_overhead``
  refinement invariant); a row that fits no existing slab triggers a full
  rebuild of that one structure — reported per structure so the
  dispatcher can bump engine epochs only for shape changes.

Everything here is host-side numpy: device placement of the changed
structures (and the engine-cache versioning) is the dispatcher's job.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .csr import (
    CSRGraph,
    EllGraph,
    csr_from_edges,
)

# Structure slots of a ``core.extend.GraphOperands`` bundle, in field order.
STRUCTURES = ("fwd", "rev", "rev_binned", "rev_binned_pack", "blocks")


# ---------------------------------------------------------------------------
# The delta itself
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of edge edits against a host CSR graph.

    Semantics (the rebuild contract): deletions apply first against the
    current edge *set*, then insertions — so ``apply_delta_csr(g, d)`` is
    edge-for-edge what ``csr_from_edges`` produces over
    ``(edges(g) - deletes) + inserts`` with ``dedup=True``. Corner cases
    a replayed delta stream produces are all well-defined no-ops:
    duplicate edges inside either batch collapse, deleting an absent edge
    does nothing, and re-inserting a present edge keeps the existing edge
    (and its weight — ``csr_from_edges``'s stable keep-first dedup, with
    surviving old edges sorted ahead of same-key inserts). Self-loops are
    ordinary edges, exactly as in ``csr_from_edges``.
    """

    add_src: np.ndarray = None  # [n_adds] int64
    add_dst: np.ndarray = None  # [n_adds] int64
    del_src: np.ndarray = None  # [n_dels] int64
    del_dst: np.ndarray = None  # [n_dels] int64
    add_weights: Optional[np.ndarray] = None  # [n_adds] float32

    def __post_init__(self):
        conv = lambda a: np.asarray(
            [] if a is None else a, dtype=np.int64
        ).reshape(-1)
        object.__setattr__(self, "add_src", conv(self.add_src))
        object.__setattr__(self, "add_dst", conv(self.add_dst))
        object.__setattr__(self, "del_src", conv(self.del_src))
        object.__setattr__(self, "del_dst", conv(self.del_dst))
        if self.add_weights is not None:
            object.__setattr__(
                self,
                "add_weights",
                np.asarray(self.add_weights, np.float32).reshape(-1),
            )
        if len(self.add_src) != len(self.add_dst):
            raise ValueError("add_src/add_dst length mismatch")
        if len(self.del_src) != len(self.del_dst):
            raise ValueError("del_src/del_dst length mismatch")
        if self.add_weights is not None and len(self.add_weights) != len(
            self.add_src
        ):
            raise ValueError("add_weights length mismatch")

    @property
    def n_adds(self) -> int:
        return len(self.add_src)

    @property
    def n_dels(self) -> int:
        return len(self.del_src)

    def touched_rows(self) -> np.ndarray:
        """Unique forward rows (source nodes) the delta names."""
        return np.unique(np.concatenate([self.add_src, self.del_src]))

    def validate(self, n_nodes: int) -> None:
        for name in ("add_src", "add_dst", "del_src", "del_dst"):
            a = getattr(self, name)
            if len(a) and (int(a.min()) < 0 or int(a.max()) >= n_nodes):
                raise ValueError(
                    f"{name} contains node ids outside [0, {n_nodes})"
                )


def random_delta(
    csr: CSRGraph, n_adds: int, n_dels: int, seed: int = 0
) -> GraphDelta:
    """Seeded delta for drivers and benches: deletes sampled (with
    replacement — duplicates exercise the dedup contract) from existing
    edges, inserts uniform over the id space (self-loops and collisions
    with live edges allowed, both defined no-op-or-keep cases)."""
    rng = np.random.default_rng(seed)
    n = csr.n_nodes
    if csr.n_edges and n_dels:
        src_all, dst_all = csr.edge_list()
        pick = rng.integers(0, csr.n_edges, size=n_dels)
        dsrc = src_all[pick].astype(np.int64)
        ddst = dst_all[pick].astype(np.int64)
    else:
        dsrc = ddst = np.zeros(0, np.int64)
    asrc = rng.integers(0, n, size=n_adds)
    adst = rng.integers(0, n, size=n_adds)
    aw = None
    if csr.weights is not None:
        aw = rng.uniform(0.1, 2.0, size=n_adds).astype(np.float32)
    return GraphDelta(asrc, adst, dsrc, ddst, add_weights=aw)


def apply_delta_csr(csr: CSRGraph, delta: GraphDelta) -> CSRGraph:
    """Apply ``delta`` to the host CSR — the semantic rebuild oracle.

    Routes through ``csr_from_edges(dedup=True)`` so duplicate / self-loop
    handling is *the same code path* a from-scratch build uses: the two
    can never disagree on degrees.
    """
    n = csr.n_nodes
    delta.validate(n)
    if delta.add_weights is not None and csr.weights is None:
        raise ValueError("delta carries add_weights but graph is unweighted")
    src, dst = csr.edge_list()
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    w = csr.weights
    if delta.n_dels:
        dkey = np.unique(delta.del_src * n + delta.del_dst)
        keep = ~np.isin(src * n + dst, dkey)
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
    asrc, adst = delta.add_src, delta.add_dst
    w_all = None
    if w is not None:
        aw = delta.add_weights
        if aw is None:
            aw = np.ones(len(asrc), np.float32)
        w_all = np.concatenate([w, aw])
    return csr_from_edges(
        n,
        np.concatenate([src, asrc]),
        np.concatenate([dst, adst]),
        weights=w_all,
        dedup=True,
    )


# ---------------------------------------------------------------------------
# Effective-edge diff
# ---------------------------------------------------------------------------


def _row_edge_keys(eff: CSRGraph, rows: np.ndarray, n: int):
    """Flattened ``src * n + dst`` keys of the effective edges of ``rows``,
    plus the weights at the same flat positions (``None`` unweighted)."""
    ptr = eff.indptr
    counts = (ptr[rows + 1] - ptr[rows]).astype(np.int64)
    flat_rows = np.repeat(rows, counts)
    offs = np.arange(int(counts.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    pos = np.repeat(ptr[rows], counts) + offs
    keys = flat_rows * n + eff.indices[pos].astype(np.int64)
    w = eff.weights[pos] if eff.weights is not None else None
    return keys, w


@dataclasses.dataclass(frozen=True)
class DeltaDiff:
    """Exactly what changed between two effective graphs, keyed for the
    per-structure folds. ``added``/``removed`` are ``src * n + dst`` edge
    keys; dirty rows are the rows whose *membership set* OR per-edge
    weights changed (rows with an unchanged set and unchanged weights keep
    identical within-row edge order in both the forward and reverse
    orientations, so they need no rewrite). Weight-only changes never make
    ``added``/``removed`` — the 0/1 block tiles don't see weights."""

    n_nodes: int
    fwd_dirty: np.ndarray  # int64 forward rows to rewrite
    rev_dirty: np.ndarray  # int64 reverse rows (dst nodes) to rewrite
    added: np.ndarray  # int64 effective edge keys
    removed: np.ndarray  # int64 effective edge keys
    # edges present in BOTH effective sets whose weight changed (a
    # delete+reinsert of the same edge at a new weight inside one delta):
    # membership-invisible, but their rows must still be rewritten
    reweighted: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64)
    )

    @property
    def n_changed_edges(self) -> int:
        return len(self.added) + len(self.removed)


def diff_effective(
    old_eff: CSRGraph, new_eff: CSRGraph, delta: GraphDelta
) -> DeltaDiff:
    """Diff the *effective* (degree-truncated) edge sets over the rows the
    delta touches. Exact under truncation: a delete can pull a previously
    truncated edge into the cap, an insert can push one out — both show up
    because we compare full per-row effective sets, not the delta's own
    edge list. On weighted graphs, edges surviving in both sets are also
    compared by weight (a delete+reinsert at a new weight changes no
    membership but must still dirty its forward and reverse rows)."""
    n = old_eff.n_nodes
    rows = delta.touched_rows()
    old_keys, old_w = _row_edge_keys(old_eff, rows, n)
    new_keys, new_w = _row_edge_keys(new_eff, rows, n)
    removed = np.setdiff1d(old_keys, new_keys)
    added = np.setdiff1d(new_keys, old_keys)
    changed = np.concatenate([added, removed])
    reweighted = np.zeros(0, np.int64)
    if old_w is not None and new_w is not None:
        # keys are globally unique (dedup'd CSR rows): intersect aligns the
        # surviving edges positionally across the two effective sets
        common, io, inew = np.intersect1d(
            old_keys, new_keys, return_indices=True
        )
        reweighted = common[old_w[io] != new_w[inew]]
    dirty = np.concatenate([changed, reweighted])
    return DeltaDiff(
        n_nodes=n,
        fwd_dirty=np.unique(dirty // n),
        rev_dirty=np.unique(dirty % n),
        added=added,
        removed=removed,
        reweighted=reweighted,
    )


# ---------------------------------------------------------------------------
# Folding into the operand structures (host mirrors, numpy, in place)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FoldReport:
    """Per-structure outcome of one ``fold_operands`` call.

    ``changed[s]``  — content differs; its device buffers need re-placing.
    ``reshaped[s]`` — the fold could not keep shapes; the structure was
    rebuilt from scratch and engines compiled against its old shapes must
    be invalidated (epoch bump).
    """

    changed: dict
    reshaped: dict
    binned_moves: int = 0  # rows re-binned between existing buckets

    @property
    def same_shape(self) -> bool:
        return not any(self.reshaped.values())

    @property
    def n_changed(self) -> int:
        return sum(bool(v) for v in self.changed.values())

    @property
    def n_reshaped(self) -> int:
        return sum(bool(v) for v in self.reshaped.values())


def _ell_row_data(eff: CSRGraph, rows: np.ndarray, width: int, n_pad: int):
    """Padded ``[len(rows), width]`` neighbor rows of ``eff`` (sentinel
    ``n_pad``), plus clipped degrees — the per-row content an ELL slab
    stores."""
    idx = np.full((len(rows), width), n_pad, np.int32)
    ptr = eff.indptr
    counts = np.minimum(ptr[rows + 1] - ptr[rows], width).astype(np.int64)
    flat = np.repeat(np.arange(len(rows), dtype=np.int64), counts)
    offs = np.arange(int(counts.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    pos = np.repeat(ptr[rows], counts) + offs
    idx[flat, offs] = eff.indices[pos]
    w = None
    if eff.weights is not None:
        w = np.zeros((len(rows), width), np.float32)
        w[flat, offs] = eff.weights[pos]
    return idx, w, counts.astype(np.int32)


def _fold_ell(ell: EllGraph, eff: CSRGraph, dirty: np.ndarray, n_pad: int):
    """Rewrite ``dirty`` rows of a host-mirror ELL slab in place.

    Returns the slab on success, ``None`` when a dirty row's new degree
    overflows the slab width (including the edgeless ``[n, 0]`` slab
    gaining its first edge) — the caller rebuilds at the new width."""
    width = int(ell.indices.shape[1])
    degs = eff.indptr[dirty + 1] - eff.indptr[dirty]
    if len(degs) and int(degs.max()) > width:
        return None
    idx, w, counts = _ell_row_data(eff, dirty, width, n_pad)
    ell.indices[dirty] = idx
    ell.degrees[dirty] = counts
    if ell.weights is not None:
        ell.weights[dirty] = w
    return ell


def _build_ell_host(eff: CSRGraph, n_pad: int) -> EllGraph:
    """Full host ELL at ``n_pad`` rows — the rebuild path when a dirty row
    overflows its slab. Width rule matches ``ell_from_csr`` + ``pad_ell``
    (max degree rounded up to a multiple of 8; genuine ``[n_pad, 0]`` slab
    when edgeless), sentinel ``n_pad``."""
    n = eff.n_nodes
    degs = eff.degrees
    cap = int(degs.max()) if n and len(degs) else 0
    if cap > 0:
        cap = -(-cap // 8) * 8
    idx, w, counts = _ell_row_data(
        eff, np.arange(n, dtype=np.int64), cap, n_pad
    )
    indices = np.full((n_pad, cap), n_pad, np.int32)
    indices[:n] = idx
    degrees = np.zeros(n_pad, np.int32)
    degrees[:n] = counts
    weights = None
    if w is not None:
        weights = np.zeros((n_pad, cap), np.float32)
        weights[:n] = w
    return EllGraph(indices=indices, degrees=degrees, weights=weights)


def _fold_binned(bn, rev: CSRGraph, dirty: np.ndarray, n_pad: int,
                 max_overhead: float = 1.1):
    """Re-bin ``dirty`` (reverse) rows inside the existing slab shapes.

    A dirty row stays in its bucket when the bucket still satisfies the
    builder's refinement invariant for its new degree
    (``deg <= width <= max_overhead * deg``, or the zero-width bucket for
    degree 0); otherwise it moves to the narrowest existing bucket that
    satisfies it, claiming a free (sentinel-perm) slot — vacated slots are
    claimable in the same pass, so swaps inside one bucket always fit.
    Preserves the perm/inverse placement contract for every untouched row.

    Returns ``(changed_cells, perm_changed, n_moves)`` where
    ``changed_cells`` is ``[(bucket, shard, slot)]`` of rewritten slab
    rows, or ``None`` when some row fits no existing bucket (degree
    outside every slab's invariant range) or a target bucket has no free
    slot — the caller rebuilds the structure (shape change)."""
    K = int(bn.perm.shape[0])
    rows_local = int(bn.inv.shape[1])
    widths = [int(s.shape[-1]) for s in bn.slabs]
    rows_b = np.asarray([int(s.shape[-2]) for s in bn.slabs], np.int64)
    ends = np.cumsum(rows_b)
    starts = ends - rows_b
    has_w = bn.slab_weights is not None
    n = rev.n_nodes

    def fits(d: int, b: int) -> bool:
        w = widths[b]
        if d == 0:
            return b == 0
        return b > 0 and w >= d and w <= max_overhead * d + 1e-9

    recs = []  # (row, shard, local, new_deg, binned_pos, bucket)
    for r in map(int, dirty):
        k, l = divmod(r, rows_local)
        d = int(rev.indptr[r + 1] - rev.indptr[r]) if r < n else 0
        p = int(bn.inv[k, l])
        b = int(np.searchsorted(ends, p, side="right"))
        recs.append((r, k, l, d, p, b))

    movers = [t for t in recs if not fits(t[3], t[5])]
    changed_cells: list = []
    perm_changed = False
    if movers:
        targets = []
        for _, _, _, d, _, _ in movers:
            cands = [b for b in range(len(widths)) if fits(d, b)]
            if not cands:
                return None
            targets.append(min(cands, key=lambda b: widths[b]))
        # free slots per (shard, bucket): positions whose perm is sentinel
        free: dict = {}
        for k in range(K):
            holes = np.nonzero(np.asarray(bn.perm[k]) == rows_local)[0]
            hb = np.searchsorted(ends, holes, side="right")
            for b in range(len(widths)):
                free[(k, b)] = sorted(
                    holes[hb == b].tolist(), reverse=True
                )  # pop() takes the lowest position — deterministic
        # pass 1: vacate every mover (their old slots become claimable)
        for (r, k, l, d, p, b) in movers:
            bn.perm[k, p] = rows_local
            if widths[b] > 0:
                slot = p - int(starts[b])
                bn.slabs[b][k, slot, :] = n_pad
                if has_w:
                    bn.slab_weights[b][k, slot, :] = 0.0
                changed_cells.append((b, k, slot))
            free[(k, b)].append(p)
            free[(k, b)].sort(reverse=True)
            perm_changed = True
        # pass 2: claim a slot in each mover's target bucket
        for (r, k, l, d, p, b), tb in zip(movers, targets):
            slots = free[(k, tb)]
            if not slots:
                return None
            p2 = int(slots.pop())
            bn.perm[k, p2] = l
            bn.inv[k, l] = p2

    # content rewrite: every dirty row at its (possibly new) slot
    for (r, k, l, d, _, _) in recs:
        p = int(bn.inv[k, l])
        b = int(np.searchsorted(ends, p, side="right"))
        if widths[b] == 0:
            continue
        slot = p - int(starts[b])
        lo = int(rev.indptr[r])
        row = bn.slabs[b][k, slot]
        row[:] = n_pad
        row[:d] = rev.indices[lo : lo + d]
        if has_w:
            wrow = bn.slab_weights[b][k, slot]
            wrow[:] = 0.0
            wrow[:d] = rev.weights[lo : lo + d]
        changed_cells.append((b, k, slot))
    return changed_cells, perm_changed, len(movers)


def _fold_pack(pack, bn, changed_cells, perm_changed: bool) -> None:
    """Mirror binned-slab rewrites into the fused-kernel pack in place.

    Pack slab ``b-1`` rows ``[0:rows_b]`` alias binned slab ``b`` rows
    (``build_pack`` only row-pads below), so changed cells copy across
    directly; when rows moved buckets, the padded perm/inverse pair is
    recomputed with ``build_pack``'s deterministic padded-position rule
    (a pure function of the unchanged shapes)."""
    has_w = pack.slab_weights is not None
    for b, k, slot in changed_cells:
        pack.slabs[b - 1][k, slot] = bn.slabs[b][k, slot]
        if has_w:
            pack.slab_weights[b - 1][k, slot] = bn.slab_weights[b][k, slot]
    if perm_changed:
        rows_raw = [int(s.shape[-2]) for s in bn.slabs]
        rows_pad = [int(s.shape[-2]) for s in pack.slabs]
        rows_local = int(bn.inv.shape[1])
        starts = np.concatenate([[0], np.cumsum(rows_raw)])[:-1]
        seg = np.asarray([rows_raw[0]] + rows_pad, np.int64)
        pstarts = np.concatenate([[0], np.cumsum(seg)])[:-1]
        bop = np.repeat(np.arange(len(rows_raw)), rows_raw)
        pp = pstarts[bop] + np.arange(int(np.sum(rows_raw))) - starts[bop]
        pack.inv_pad[:] = pp[np.asarray(bn.inv)].astype(np.int32)
        pack.perm_pad[:] = rows_local
        pack.perm_pad[:, pp] = np.asarray(bn.perm)


def _fold_blocks(sb, new_eff: CSRGraph, added: np.ndarray,
                 removed: np.ndarray, n_pad: int):
    """Recompute only the ``[B, B]`` tiles touched by changed edges.

    A tile that gains its first edge claims a free (sentinel-col) slot in
    its shard's tile list; a tile that empties is zeroed and its slot
    freed. Returns whether anything changed, or ``None`` when a new tile
    needs a slot and the shard's list is full — the caller rebuilds (the
    per-shard tile capacity ``nb`` is a shape)."""
    K, nb, B, _ = (int(d) for d in sb.blocks.shape)
    rows_local = n_pad // K
    G = n_pad // B  # sentinel col-block id of padding tiles
    n = new_eff.n_nodes
    keys = np.concatenate([added, removed])
    u = keys // n
    v = keys % n
    tiles = sorted(
        set(
            zip(
                (u // rows_local).tolist(),
                ((u % rows_local) // B).tolist(),
                (v // B).tolist(),
            )
        )
    )
    slot_of: dict = {}
    free: dict = {}
    bcols = sb.block_cols
    brows = sb.block_rows
    for k in range(K):
        live = np.nonzero(np.asarray(bcols[k]) != G)[0]
        for s in live:
            slot_of[(k, int(brows[k, s]), int(bcols[k, s]))] = int(s)
        free[k] = sorted(
            np.nonzero(np.asarray(bcols[k]) == G)[0].tolist(), reverse=True
        )
    changed = False
    ptr = new_eff.indptr
    for (k, rb, cb) in tiles:
        r0 = k * rows_local + rb * B
        r1 = min(r0 + B, n)
        tile = np.zeros((B, B), np.int8)
        if r1 > r0:
            rows = np.arange(r0, r1, dtype=np.int64)
            counts = (ptr[rows + 1] - ptr[rows]).astype(np.int64)
            flat = np.repeat(rows - r0, counts)
            offs = np.arange(int(counts.sum()), dtype=np.int64) - np.repeat(
                np.cumsum(counts) - counts, counts
            )
            pos = np.repeat(ptr[rows], counts) + offs
            dsts = new_eff.indices[pos].astype(np.int64)
            sel = (dsts >= cb * B) & (dsts < (cb + 1) * B)
            tile[flat[sel], dsts[sel] - cb * B] = 1
        s = slot_of.get((k, rb, cb))
        if tile.any():
            if s is None:
                if not free[k]:
                    return None
                s = free[k].pop()
                brows[k, s] = rb
                bcols[k, s] = cb
                slot_of[(k, rb, cb)] = s
            sb.blocks[k, s] = tile
            changed = True
        elif s is not None:
            sb.blocks[k, s] = 0
            brows[k, s] = 0
            bcols[k, s] = G
            del slot_of[(k, rb, cb)]
            free[k].append(s)
            free[k].sort(reverse=True)
            changed = True
    return changed


def fold_operands(host, old_eff: CSRGraph, new_eff: CSRGraph,
                  diff: DeltaDiff):
    """Fold one delta's effective changes into a host-mirror operand
    bundle (numpy leaves; mutated in place where shapes allow).

    ``host`` is any object with the ``GraphOperands`` structure slots
    (``fwd`` required; the rest optional). Returns
    ``(structures_dict, FoldReport)`` where the dict maps each slot name
    to its post-fold structure — in-place-folded mirrors, or fresh
    rebuilds for the slots the report marks ``reshaped``.
    """
    del old_eff  # the diff already carries everything the folds need
    # local imports: csr builders only (this module stays importable
    # without jax having initialized any backend state)
    from .csr import binned_rev_csr, sharded_blocks_from_csr

    n_pad = int(host.fwd.indices.shape[0])
    changed = {s: False for s in STRUCTURES}
    reshaped = {s: False for s in STRUCTURES}
    moves = 0

    fwd = host.fwd
    if len(diff.fwd_dirty):
        if _fold_ell(fwd, new_eff, diff.fwd_dirty, n_pad) is None:
            fwd = _build_ell_host(new_eff, n_pad)
            reshaped["fwd"] = True
        changed["fwd"] = True

    rev_csr = None
    rev = getattr(host, "rev", None)
    if rev is not None and len(diff.rev_dirty):
        rev_csr = new_eff.reverse()
        if _fold_ell(rev, rev_csr, diff.rev_dirty, n_pad) is None:
            rev = _build_ell_host(rev_csr, n_pad)
            reshaped["rev"] = True
        changed["rev"] = True

    bn = getattr(host, "rev_binned", None)
    pack = getattr(host, "rev_binned_pack", None)
    if bn is not None and len(diff.rev_dirty):
        if rev_csr is None:
            rev_csr = new_eff.reverse()
        out = _fold_binned(bn, rev_csr, diff.rev_dirty, n_pad)
        if out is None:
            K = int(bn.perm.shape[0])
            bn = _to_numpy(binned_rev_csr(new_eff, n_pad, K))
            reshaped["rev_binned"] = True
            if pack is not None:
                from ..kernels.binned_pull.ops import build_pack

                pack = _to_numpy(build_pack(bn, n_pad))
                reshaped["rev_binned_pack"] = True
                changed["rev_binned_pack"] = True
        else:
            cells, perm_changed, moves = out
            if pack is not None and (cells or perm_changed):
                _fold_pack(pack, bn, cells, perm_changed)
                changed["rev_binned_pack"] = True
        changed["rev_binned"] = True

    sb = getattr(host, "blocks", None)
    if sb is not None and diff.n_changed_edges:
        out = _fold_blocks(sb, new_eff, diff.added, diff.removed, n_pad)
        if out is None:
            K = int(sb.blocks.shape[0])
            B = int(sb.blocks.shape[2])
            sb = _to_numpy(sharded_blocks_from_csr(new_eff, n_pad, K, B))
            reshaped["blocks"] = True
            changed["blocks"] = True
        elif out:
            changed["blocks"] = True

    structs = {
        "fwd": fwd,
        "rev": rev,
        "rev_binned": bn,
        "rev_binned_pack": pack,
        "blocks": sb,
    }
    return structs, FoldReport(
        changed=changed, reshaped=reshaped, binned_moves=moves
    )


def _to_numpy(struct):
    """Writable host copy of a (possibly device-backed) operand structure.

    ``np.array(x)`` (not ``np.asarray``) — views of jax buffers are
    read-only and the folds write in place."""
    import jax

    return jax.tree.map(lambda x: np.array(x), struct)


# ---------------------------------------------------------------------------
# Dispatcher-facing report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaReport:
    """What one ``QueryDispatcher.apply_delta`` did."""

    version: int  # the new operands_version
    n_adds: int
    n_dels: int
    changed_edges: int  # effective edge inserts + removes
    dirty_fwd_rows: int
    dirty_rev_rows: int
    bundles: int  # operand bundles folded
    structures_changed: int  # device buffers re-placed
    structures_rebuilt: int  # shape-changing rebuilds (epoch bumps)
    binned_moves: int  # rows re-binned between existing buckets
    engines_invalidated: int  # compiled engines dropped from the cache

    @property
    def same_shape(self) -> bool:
        """True when every structure kept its shapes — compiled engines
        all stayed warm (the mutate-stream fast path)."""
        return self.structures_rebuilt == 0
