"""Fault-tolerant checkpointing: sharded npz + JSON manifest.

Design for 1000+-node pods (orbax is not available offline):
- atomic: write to ``step_N.tmp/`` then rename — a crash mid-write never
  corrupts the latest checkpoint;
- async: a background writer thread overlaps serialization with training;
- elastic: the manifest stores the LOGICAL tree structure + global shapes,
  not device layouts — ``restore`` re-shards onto whatever mesh the new job
  has (scale up/down across restarts);
- self-pruning: keep the last ``keep`` checkpoints.

On a real multi-host pod each host writes its addressable shards and the
manifest is written by host 0 (the code paths are the same; this container is
single-host).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._async = async_write
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot (device->host copy) is taken NOW; writing may be async."""
        if self._err:
            raise RuntimeError("async checkpoint writer died") from self._err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._async and not blocking:
            self._q.put((step, host_tree))
        else:
            self._write(step, host_tree)

    def wait(self):
        if self._async:
            self._q.join()
        if self._err:
            raise RuntimeError("async checkpoint writer died") from self._err

    def _worker(self):
        while True:
            step, tree = self._q.get()
            try:
                self._write(step, tree)
            except BaseException as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree: Any):
        tmp = os.path.join(self.directory, f"step_{step}.tmp")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten_with_paths(host_tree)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        arrays = {}
        for i, (key, leaf) in enumerate(sorted(leaves.items())):
            name = f"a{i}"
            arr = np.asarray(leaf)
            logical_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or logical_dtype in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"
            ):
                # npz can't round-trip ml_dtypes — store the raw bits
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            arrays[name] = arr
            manifest["leaves"][key] = {
                "file": name,
                "shape": list(np.shape(leaf)),
                "dtype": logical_dtype,
            }
        np.savez(os.path.join(tmp, "shards.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s}"), ignore_errors=True
            )

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``like``. If ``shardings`` (a tree of
        NamedSharding) is given, leaves are device_put with it — this is the
        elastic path: the stored checkpoint is mesh-agnostic, the new mesh can
        differ from the writer's."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shards.npz"))
        leaves = _flatten_with_paths(like)
        sh_leaves = _flatten_with_paths(shardings) if shardings else {}
        restored = {}
        for key, leaf in leaves.items():
            meta = manifest["leaves"][key]
            arr = data[meta["file"]]
            if str(arr.dtype) != meta["dtype"]:
                import ml_dtypes

                arr = arr.view(np.dtype(meta["dtype"]))
            if shardings and key in sh_leaves:
                restored[key] = jax.device_put(arr, sh_leaves[key])
            else:
                restored[key] = jax.numpy.asarray(arr)
        # rebuild tree in original structure
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        ordered = [
            restored["/".join(str(p) for p in path)] for path, _ in flat
        ]
        return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef") else treedef, ordered), step
