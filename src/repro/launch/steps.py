"""Per-cell step programs: every assigned (arch × shape) cell as a
jit-loweable function with abstract inputs + production shardings.

``build_cell(arch_id, shape_name, mesh, multi_pod)`` returns a ``Cell``
holding the step function, ShapeDtypeStruct arguments, input shardings and
the analytic MODEL_FLOPS for the roofline ratio. ``launch/dryrun`` lowers and
compiles each cell; nothing here allocates device memory.

Step kinds per family:
- LM train:     loss + grad + AdamW update          (train_step)
- LM prefill:   prompt -> last logits + KV caches   (prefill_step)
- LM decode:    one new token against a KV cache    (serve_step)
- GNN:          full-graph / sampled-minibatch / batched-molecule train_step
- recsys:       CTR train / online & bulk serve / 1M-candidate retrieval
- paper engine: the IFE query engine at full published graph scale
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import mesh_context
from ..configs import base as cfgbase
from ..core.dispatcher import build_engine, pad_sources, _axes_size
from ..core.policies import POLICIES
from ..graph.csr import EllGraph
from ..graph.partition import padded_n
from ..models import dcn_v2 as dcn
from ..models import transformer as tfm
from ..models.gnn import equiformer_v2 as eqv2_m
from ..models.gnn import mace as mace_m
from ..models.gnn import pna as pna_m
from ..models.gnn import schnet as schnet_m
from ..nn.module import (
    set_activation_rules,
    sharding_rules,
    shardings_from_axes,
    split_boxed,
)
from ..optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update

GNN_MODULES = {
    "mace": mace_m,
    "equiformer-v2": eqv2_m,
    "pna": pna_m,
    "schnet": schnet_m,
}


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable  # ready to jit (or already jitted for paper engine)
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: Any  # tuple matching args, or None (shard_map programs)
    model_flops: float  # analytic useful FLOPs per step execution
    iters_scale: float = 1.0  # roofline multiplier for dynamic while bodies
    notes: str = ""
    prejitted: bool = False  # fn is already jax.jit-wrapped (paper engine)
    donate: tuple = ()  # donated arg indices (in-place update semantics)
    out_shardings: Any = None


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _all_axes(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def _ns(mesh, *spec_parts):
    return NamedSharding(mesh, P(*spec_parts))


def _sanitize(params, shardings, mesh):
    """jit(in_shardings=...) requires dims divisible by their mesh axes
    (unlike with_sharding_constraint, which pads). Drop the spec on any
    param dim that does not divide — e.g. dcn-v2's 429-wide cross kernels
    or PNA's 75-wide towers stay replicated on that dim."""

    def fix(leaf, sh):
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        out = []
        for dim, part in zip(leaf.shape, spec):
            axes = (part,) if isinstance(part, str) else (part or ())
            size = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
                if axes else 1
            out.append(part if size > 1 and dim % size == 0 else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, params, shardings)


# =========================================================================
# LM family
# =========================================================================

# microbatch counts tuned against measured single-shot activation temps
_N_MICRO = {
    "deepseek-coder-33b": 4,
    "olmoe-1b-7b": 4,
    "llama4-maverick-400b-a17b": 8,
}

def _lm_abstract_params(cfg, mesh, rules):
    boxed = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg))
    params, axes = split_boxed(boxed)
    shard = _sanitize(params, shardings_from_axes(axes, mesh, rules), mesh)
    return params, shard


def _lm_attn_flops(cfg, B, S, causal=True, cache_w=None):
    """Attention matmul FLOPs (QK^T + PV), fwd only, all layers.

    cache_w: decode mode — per-token attention against a W-deep cache."""
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if cache_w is not None:
            w_eff = min(cfg.window, cache_w) if kind in ("local", "chunk") \
                else cache_w
            total += 4.0 * B * w_eff * cfg.n_heads * cfg.d_head
        else:
            s_eff = min(cfg.window, S) if kind in ("local", "chunk") else S
            # causal ~ half the S x s_eff rectangle
            total += 4.0 * B * S * s_eff * cfg.n_heads * cfg.d_head * (
                0.5 if causal else 1.0
            )
    return total


def _lm_cell(spec, shape, mesh, multi_pod) -> Cell:
    cfg = spec.full_config()
    if shape.kind == "train":
        # launcher policy (not part of the published arch configs):
        # "minimal" named remat saves the two d_model-wide sublayer outputs
        # per layer; for deep/wide models even those stacks exceed HBM, so
        # fall back to carry-only ("full") remat — ~33% extra fwd compute
        # for O(L·d) saved bytes
        dims_ = shape.dims
        dp = 16  # data-axis width (both meshes)
        n_micro = _N_MICRO.get(spec.arch_id, 1)
        saved = (3 * cfg.n_layers * (dims_["global_batch"] // dp // n_micro)
                 * (dims_["seq_len"] // 16) * cfg.d_model * 2)
        cfg = dataclasses.replace(
            cfg, remat="full" if saved > 6e9 else "minimal"
        )
    # train/prefill: sequence-parallel residual stream (scan carries saved
    # for backward shrink by the TP degree); decode: TP activations.
    rules = sharding_rules(
        multi_pod, seq_parallel=shape.kind in ("train", "prefill")
    )
    set_activation_rules(rules)
    params, pshard = _lm_abstract_params(cfg, mesh, rules)
    ba = _batch_axes(multi_pod)
    dims = shape.dims
    B, S = dims["global_batch"], dims["seq_len"]
    N = cfg.active_params()

    if shape.kind == "train":
        # llama4-maverick's 400B total params need bf16 moments to fit
        moment_dtype = (
            jnp.bfloat16 if cfg.total_params() > 1e11 else jnp.float32
        )
        ocfg = AdamWConfig(lr=3e-4, moment_dtype=moment_dtype)
        opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        opt_shard = AdamWState(
            step=_ns(mesh), mu=pshard, nu=pshard
        )
        batch = {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
        bshard = {k: _ns(mesh, ba, None) for k in batch}

        # microbatch gradient accumulation: activation + MoE-dispatch temps
        # scale with per-device tokens; n_micro is tuned per arch from the
        # measured single-shot footprints (EXPERIMENTS.md §Dry-run). The
        # gradient buffer (param-sharded f32) is the only extra state.
        def train_step(params, opt, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(tfm.loss_fn)(
                    params, cfg, batch
                )
            else:
                mb = jax.tree.map(
                    lambda a: a.reshape(n_micro, B // n_micro, *a.shape[1:]),
                    batch,
                )

                def micro(acc, b):
                    l, g = jax.value_and_grad(tfm.loss_fn)(params, cfg, b)
                    return jax.tree.map(jnp.add, acc, g), l

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, losses = jax.lax.scan(micro, zeros, mb)
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                loss = losses.mean()
            new_p, new_o, gnorm = adamw_update(grads, opt, params, ocfg)
            return new_p, new_o, loss, gnorm

        flops = 6.0 * N * (B * S) + 3.0 * _lm_attn_flops(cfg, B, S)
        return Cell(
            spec.arch_id, shape.name, "train", train_step,
            (params, opt, batch), (pshard, opt_shard, bshard), flops,
            notes=f"6ND={6.0 * N * B * S:.3e} n_micro={n_micro}",
            donate=(0, 1),
        )

    if shape.kind == "prefill":
        tokens = sds((B, S), jnp.int32)

        def prefill_step(params, tokens):
            return tfm.prefill(params, cfg, tokens, max_seq=S)

        flops = 2.0 * N * (B * S) + _lm_attn_flops(cfg, B, S)
        return Cell(
            spec.arch_id, shape.name, "prefill", prefill_step,
            (params, tokens), (pshard, _ns(mesh, ba, None)), flops,
        )

    # decode: one new token against a seq_len-deep KV cache
    assert shape.kind == "decode", shape.kind
    caches = jax.eval_shape(
        lambda: tfm.init_model_cache(cfg, B, S, jnp.bfloat16)
    )
    # KV-cache sharding: batch over data axes when it divides; the cache
    # sequence dim is sharded over "model" (decode_32k) or over ALL axes
    # (long_500k, batch=1) — flash-decoding-style distributed attention.
    data_sz = _axes_size(mesh, ba)
    if B >= data_sz and B % data_sz == 0:
        seq_axes = ("model",)
        cache_batch = ba
    else:
        seq_axes = ba + ("model",)
        cache_batch = None

    def _cache_spec(leaf):
        if leaf.ndim == 5:  # k/v: [groups, B, W, KV, hd]
            return _ns(mesh, None, cache_batch, seq_axes, None, None)
        assert leaf.ndim == 2  # slot_pos: [groups, W]
        return _ns(mesh, None, seq_axes)

    cache_shard = jax.tree.map(_cache_spec, caches)
    tokens = sds((B, 1), jnp.int32)
    pos = sds((), jnp.int32)

    def serve_step(params, caches, tokens, pos):
        return tfm.decode(params, cfg, caches, tokens, pos)

    flops = 2.0 * N * B + _lm_attn_flops(cfg, B, None, cache_w=S)
    return Cell(
        spec.arch_id, shape.name, "decode", serve_step,
        (params, caches, tokens, pos),
        (pshard, cache_shard, _ns(mesh, cache_batch, None), _ns(mesh)),
        flops,
        notes=f"KV cache W={S}, seq sharded over {seq_axes}",
        donate=(1,),
    )


def lm_components(arch_id: str, shape_name: str, mesh: Mesh,
                  multi_pod: bool) -> list:
    """Compositional roofline probes for LM cells.

    XLA's HLO cost analysis counts a while/scan body ONCE regardless of trip
    count, so the monolithic cell under-reports everything inside the
    layer-scan and the CE-chunk scan. Each component here is a standalone
    program with a STATIC trip multiplier (Cell.iters_scale); summing
    trips x terms reconstructs the true per-step cost:

      train:   n_groups x layer_group(fwd+bwd) + (S/ce_chunk) x ce_chunk
               + 1 x optimizer update (+ embedding, folded into ce/opt)
      prefill: n_groups x layer_group(fwd)     + 1 x unembed(last position)
      decode:  n_groups x decode_group         + 1 x unembed(one token)
    """
    spec = cfgbase.get(arch_id)
    shape = {s.name: s for s in spec.shapes}[shape_name]
    cfg = spec.full_config()
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat="minimal")
    rules = sharding_rules(
        multi_pod, seq_parallel=shape.kind in ("train", "prefill")
    )
    set_activation_rules(rules)
    params, pshard = _lm_abstract_params(cfg, mesh, rules)
    ba = _batch_axes(multi_pod)
    B, S = shape.dims["global_batch"], shape.dims["seq_len"]
    G = cfg.n_groups

    # one group's params: drop the leading stack dim
    gparams = jax.tree.map(
        lambda l: sds(l.shape[1:], l.dtype), params["blocks"]
    )
    gshard = jax.tree.map(
        lambda l, sh: NamedSharding(mesh, P(*sh.spec[1:])),
        params["blocks"], pshard["blocks"],
    )
    unemb_key = "embed" if cfg.tie_embeddings else "unembed"
    emb = params[unemb_key]["table"]
    emb_sh = pshard[unemb_key]["table"]
    res_sharding = _ns(
        mesh, ba, "model" if shape.kind in ("train", "prefill") else None,
        None,
    )
    comps = []

    if shape.kind in ("train", "prefill"):
        x = sds((B, S, cfg.d_model), cfg.dtype)
        pos = sds((B, S), jnp.int32)

        def group_fwd(gp, x, positions):
            for j in range(cfg.group_size):
                x, _ = tfm._layer_apply(gp[f"layer_{j}"], cfg, j, x,
                                        positions)
            return x

        if shape.kind == "train":
            body = tfm._remat(cfg, group_fwd)

            def group_fwd_bwd(gp, x, positions):
                y, vjp = jax.vjp(lambda g, xx: body(g, xx, positions), gp, x)
                dg, dx = vjp(jnp.ones_like(y))
                return dg, dx

            comps.append(Cell(
                arch_id, shape_name, "comp", group_fwd_bwd,
                (gparams, x, pos),
                (gshard, res_sharding, _ns(mesh, ba, None)),
                0.0, iters_scale=float(G), notes="layer_group fwd+bwd",
                out_shardings=(gshard, res_sharding),
            ))

            C = min(cfg.ce_chunk, S)
            xc = sds((B, C, cfg.d_model), cfg.dtype)
            yc = sds((B, C), jnp.int32)

            def ce_chunk(table, x_c, y_c):
                p = {"embed": {"table": table}}

                def nll(table_, x_):
                    logits = tfm._unembed(
                        {unemb_key: {"table": table_}}, cfg, x_
                    )
                    logp = jax.nn.log_softmax(logits, axis=-1)
                    return -jnp.take_along_axis(
                        logp, y_c[..., None], axis=-1
                    ).sum()

                loss, vjp = jax.vjp(nll, table, x_c)
                return vjp(jnp.float32(1.0))

            comps.append(Cell(
                arch_id, shape_name, "comp", ce_chunk,
                (emb, xc, yc),
                (emb_sh, res_sharding, _ns(mesh, ba, None)),
                0.0, iters_scale=float(S // C), notes="ce_chunk fwd+bwd",
                out_shardings=(emb_sh, res_sharding),
            ))

            moment_dtype = (
                jnp.bfloat16 if cfg.total_params() > 1e11 else jnp.float32
            )
            ocfg = AdamWConfig(lr=3e-4, moment_dtype=moment_dtype)
            opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
            opt_shard = AdamWState(step=_ns(mesh), mu=pshard, nu=pshard)

            def opt_update(grads, opt, params):
                return adamw_update(grads, opt, params, ocfg)[:2]

            comps.append(Cell(
                arch_id, shape_name, "comp", opt_update,
                (params, opt, params), (pshard, opt_shard, pshard),
                0.0, iters_scale=1.0, notes="optimizer update",
                donate=(1, 2),
            ))
        else:  # prefill: fwd only + per-group kv materialization
            def group_prefill(gp, x, positions):
                caches = {}
                for j in range(cfg.group_size):
                    key = f"layer_{j}"
                    s = cfg.attn_settings(cfg.layer_kind(j))
                    from ..nn.attention import prefill_kv

                    xin = tfm._norm(cfg, gp[key]["ln_attn"], x)
                    caches[key] = prefill_kv(gp[key]["attn"], s, xin,
                                             positions, S)
                    x, _ = tfm._layer_apply(gp[key], cfg, j, x, positions)
                return x, caches

            comps.append(Cell(
                arch_id, shape_name, "comp", group_prefill,
                (gparams, x, pos),
                (gshard, res_sharding, _ns(mesh, ba, None)),
                0.0, iters_scale=float(G), notes="layer_group prefill",
            ))

            xe = sds((B, 1, cfg.d_model), cfg.dtype)

            def unembed_last(table, x_):
                return tfm._unembed({unemb_key: {"table": table}}, cfg, x_)

            comps.append(Cell(
                arch_id, shape_name, "comp", unembed_last,
                (emb, xe), (emb_sh, _ns(mesh, ba, None, None)),
                0.0, iters_scale=1.0, notes="unembed last",
            ))
        return comps

    # decode
    assert shape.kind == "decode"
    caches = jax.eval_shape(
        lambda: tfm.init_model_cache(cfg, B, S, jnp.bfloat16)
    )
    gcache = jax.tree.map(lambda a: sds(a.shape[1:], a.dtype), caches)
    data_sz = _axes_size(mesh, ba)
    if B >= data_sz and B % data_sz == 0:
        seq_axes, cache_batch = ("model",), ba
    else:
        seq_axes, cache_batch = ba + ("model",), None

    def _cspec(leaf):
        if leaf.ndim == 4:
            return _ns(mesh, cache_batch, seq_axes, None, None)
        return _ns(mesh, seq_axes)

    gcache_sh = jax.tree.map(_cspec, gcache)
    x = sds((B, 1, cfg.d_model), cfg.dtype)
    pos = sds((), jnp.int32)

    def decode_group(gp, gc, x, pos):
        new = {}
        for j in range(cfg.group_size):
            key = f"layer_{j}"
            x, c = tfm._layer_decode(gp[key], cfg, j, x, gc[key], pos)
            new[key] = c
        return x, new

    comps.append(Cell(
        arch_id, shape_name, "comp", decode_group,
        (gparams, gcache, x, pos),
        (gshard, gcache_sh, _ns(mesh, cache_batch, None, None), _ns(mesh)),
        0.0, iters_scale=float(G), notes="decode group", donate=(1,),
    ))

    def unembed_tok(table, x_):
        return tfm._unembed({unemb_key: {"table": table}}, cfg, x_)

    comps.append(Cell(
        arch_id, shape_name, "comp", unembed_tok,
        (emb, x), (emb_sh, _ns(mesh, cache_batch, None, None)),
        0.0, iters_scale=1.0, notes="unembed token",
    ))
    return comps


# =========================================================================
# GNN family
# =========================================================================

def _round_up(x, m):
    return -(-x // m) * m


def _gnn_batch_specs(arch_id, cfg, n, e, d_feat, mesh, multi_pod):
    """Abstract GNN batch + shardings. Node arrays shard over the batch
    (fsdp) axes; edge arrays are DESTINATION-ALIGNED SLABS (one slab per
    node shard, see models/gnn/common.set_edge_slabs) sharded over all axes
    — slab dim over the node shards, slab interiors over "model"."""
    from ..models.gnn import common as gnn_common

    aa = _all_axes(multi_pod)
    ba = _batch_axes(multi_pod)
    n_dev = _axes_size(mesh, aa)
    k_slabs = _axes_size(mesh, ba)
    gnn_common.set_edge_slabs(k_slabs)
    e_pad = _round_up(e, n_dev * k_slabs // math.gcd(n_dev, k_slabs))
    n_pad = _round_up(n, k_slabs)
    batch = {
        "edge_src": sds((e_pad,), jnp.int32),
        "edge_dst": sds((e_pad,), jnp.int32),
    }
    shard = {
        "edge_src": _ns(mesh, aa),
        "edge_dst": _ns(mesh, aa),
    }
    geometric = arch_id != "pna"
    if geometric:
        batch["positions"] = sds((n_pad, 3), jnp.float32)
        batch["species"] = sds((n_pad,), jnp.int32)
        shard["positions"] = _ns(mesh, ba, None)
        shard["species"] = _ns(mesh, ba)
    if d_feat:
        batch["node_feat"] = sds((n_pad, d_feat), jnp.float32)
        shard["node_feat"] = _ns(mesh, ba, None)
    return batch, shard, n_pad, e_pad


def _gnn_flops(arch_id, cfg, n, e):
    """Analytic useful FLOPs for one fwd pass (documented approximations;
    2 FLOPs per MAC). GNN message passing is gather/scatter-bound, so these
    count only the dense contractions."""
    d = cfg.d_hidden
    if arch_id == "pna":
        # per layer: 12 aggregated features of width d -> d (tower MLP) on
        # nodes + per-edge message transform d->d
        per = 2.0 * e * d * d + 2.0 * n * (12 * d) * d
        return cfg.n_layers * per + 2.0 * n * cfg.d_feat * d
    if arch_id == "schnet":
        # interaction: edge filter (n_rbf->d->d) + node d->d mixes
        per = 2.0 * e * (cfg.n_rbf * d + d * d) + 3 * 2.0 * n * d * d
        return cfg.n_interactions * per
    if arch_id == "mace":
        lm = (cfg.l_max + 1) ** 2
        # A-basis: edges contract rbf·Y·h (d·lm each); product basis:
        # correlation-order Gaunt contractions on nodes (lm^2·d per order)
        per = 2.0 * e * d * lm * (cfg.n_rbf + lm) + (
            2.0 * n * d * lm * lm * cfg.correlation_order
        ) + 2.0 * n * d * d * lm
        return cfg.n_layers * per
    if arch_id == "equiformer-v2":
        lm = (cfg.l_max + 1) ** 2
        m_width = 2 * cfg.m_max + 1
        # eSCN SO(2) conv per edge: O(lm * m_width * d^2) after alignment,
        # + attention scores/values per edge
        per = 2.0 * e * (lm * m_width * d * d / max(cfg.l_max, 1) + 2 * d * d)
        per += 2.0 * n * d * d * 4  # node FFN
        return cfg.n_layers * per
    raise ValueError(arch_id)


def _gnn_cell(spec, shape, mesh, multi_pod) -> Cell:
    module = GNN_MODULES[spec.arch_id]
    cfg = spec.full_config()
    rules = sharding_rules(multi_pod)
    set_activation_rules(rules)
    dims = shape.dims
    ba = _batch_axes(multi_pod)

    if shape.kind == "full_graph":
        n, e, d_feat = dims["n_nodes"], dims["n_edges"], dims["d_feat"]
        if spec.arch_id == "pna":
            n_out = 47 if shape.name == "ogb_products" else 40
            cfg = dataclasses.replace(cfg, d_feat=d_feat, n_out=n_out)
        else:
            # geometric archs read species+positions; raw features are
            # additionally projected in via d_feat
            cfg = dataclasses.replace(cfg, d_feat=d_feat, n_out=8)
        batch, bshard, n_pad, e_pad = _gnn_batch_specs(
            spec.arch_id, cfg, n, e, cfg.d_feat, mesh, multi_pod
        )
        batch["targets"] = sds((n_pad, cfg.n_out), jnp.float32)
        bshard["targets"] = _ns(mesh, ba, None)
        seeds = None
        n_eff, e_eff = n, e
    elif shape.kind == "minibatch":
        bn = dims["batch_nodes"]
        f1, f2 = dims["fanout"]
        n_pad0 = bn * (1 + f1 + f1 * f2)  # 1024·166 sampled tree nodes
        e = bn * (f1 + f1 * f2)
        cfg = dataclasses.replace(cfg, n_out=8) if spec.arch_id != "pna" \
            else dataclasses.replace(cfg, d_feat=100, n_out=47)
        batch, bshard, n_pad, e_pad = _gnn_batch_specs(
            spec.arch_id, cfg, n_pad0, e, cfg.d_feat, mesh, multi_pod
        )
        batch["targets"] = sds((bn, cfg.n_out), jnp.float32)
        bshard["targets"] = _ns(mesh, ba, None)
        seeds = bn
        n_eff, e_eff = n_pad0, e
    else:  # molecule: disjoint union of 128 small graphs
        assert shape.kind == "batched"
        bsz, npg, epg = dims["batch"], dims["n_nodes"], dims["n_edges"]
        n, e = bsz * npg, bsz * epg
        cfg = dataclasses.replace(cfg, n_out=1) if spec.arch_id != "pna" \
            else dataclasses.replace(cfg, d_feat=16, n_out=1)
        batch, bshard, n_pad, e_pad = _gnn_batch_specs(
            spec.arch_id, cfg, n, e, cfg.d_feat, mesh, multi_pod
        )
        batch["graph_ids"] = sds((n_pad,), jnp.int32)
        bshard["graph_ids"] = _ns(mesh, ba)
        batch["targets"] = sds((bsz,), jnp.float32)
        bshard["targets"] = _ns(mesh, ba)
        seeds = ("graph", bsz)
        n_eff, e_eff = n, e

    boxed = jax.eval_shape(lambda: module.init(jax.random.PRNGKey(0), cfg))
    params, axes = split_boxed(boxed)
    pshard = _sanitize(params, shardings_from_axes(axes, mesh, rules), mesh)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
    opt_shard = AdamWState(step=_ns(mesh), mu=pshard, nu=pshard)
    n_graphs = seeds[1] if isinstance(seeds, tuple) else None

    def loss_fn(p, batch):
        b = dict(batch)
        targets = b.pop("targets")
        if n_graphs is not None:
            b["n_graphs"] = n_graphs
        out = module.apply(p, cfg, b)
        if n_graphs is not None:
            pred = out["graph_out"][:, 0]
        elif isinstance(seeds, int):
            pred = out["node_out"][:seeds]
        else:
            pred = out["node_out"]
        return jnp.mean(jnp.square(pred - targets))

    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_o, gnorm = adamw_update(grads, opt, params, ocfg)
        return new_p, new_o, loss, gnorm

    flops = 3.0 * _gnn_flops(spec.arch_id, cfg, n_eff, e_eff)  # fwd+bwd
    return Cell(
        spec.arch_id, shape.name, shape.kind, train_step,
        (params, opt, batch), (pshard, opt_shard, bshard), flops,
        notes=f"n={n_eff} e={e_eff}",
        donate=(0, 1),
    )


# =========================================================================
# recsys (dcn-v2)
# =========================================================================

def _dcn_flops(cfg, B, fwd_only=False):
    d0 = cfg.x0_dim
    f = 2.0 * B * d0 * d0 * cfg.n_cross_layers
    d_in = d0
    for d_out in cfg.mlp:
        f += 2.0 * B * d_in * d_out
        d_in = d_out
    f += 2.0 * B * d_in  # head
    # embedding gather ~ bytes not flops; count the segment adds
    f += B * cfg.n_sparse * cfg.embed_dim
    return f if fwd_only else 3.0 * f


def _recsys_cell(spec, shape, mesh, multi_pod) -> Cell:
    cfg = spec.full_config()
    rules = sharding_rules(multi_pod)
    set_activation_rules(rules)
    ba = _batch_axes(multi_pod)
    boxed_and_offsets = jax.eval_shape(
        lambda: dcn.init(jax.random.PRNGKey(0), cfg)[0]
    )
    params, axes = split_boxed(boxed_and_offsets)
    pshard = _sanitize(params, shardings_from_axes(axes, mesh, rules), mesh)
    # offsets are tiny static metadata (field boundaries in the fused table)
    offsets = np.concatenate(
        [[0], np.cumsum(np.asarray(cfg.field_vocabs))[:-1]]
    ).astype(np.int32)
    offsets = jnp.asarray(offsets)
    dims = shape.dims
    B = dims["batch"]
    if B % _axes_size(mesh, ba) != 0:
        ba = None  # retrieval_cand: a single query replicates

    def make_batch(with_labels):
        b = {
            "dense": sds((B, cfg.n_dense), jnp.float32),
            "sparse": sds((B, cfg.n_sparse), jnp.int32),
        }
        s = {
            "dense": _ns(mesh, ba, None),
            "sparse": _ns(mesh, ba, None),
        }
        if with_labels:
            b["labels"] = sds((B,), jnp.float32)
            s["labels"] = _ns(mesh, ba)
        return b, s

    if shape.kind == "train":
        ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        opt_shard = AdamWState(step=_ns(mesh), mu=pshard, nu=pshard)
        batch, bshard = make_batch(True)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(dcn.loss_fn)(
                params, cfg, batch, offsets
            )
            new_p, new_o, gnorm = adamw_update(grads, opt, params, ocfg)
            return new_p, new_o, loss, gnorm

        return Cell(
            spec.arch_id, shape.name, "train", train_step,
            (params, opt, batch), (pshard, opt_shard, bshard),
            _dcn_flops(cfg, B),
            donate=(0, 1),
        )

    if shape.kind in ("serve", "bulk"):
        batch, bshard = make_batch(False)

        def serve_step(params, batch):
            return dcn.forward(params, cfg, batch, offsets)

        return Cell(
            spec.arch_id, shape.name, shape.kind, serve_step,
            (params, batch), (pshard, bshard),
            _dcn_flops(cfg, B, fwd_only=True),
        )

    assert shape.kind == "retrieval"
    # pad the candidate set to the device count (serving systems pad the
    # last ANN shard anyway); scores for pad rows are -inf'able downstream
    nc = _round_up(dims["n_candidates"], mesh.size)
    batch, bshard = make_batch(False)
    cand = sds((nc, cfg.retrieval_dim), jnp.float32)
    cand_shard = _ns(mesh, _all_axes(multi_pod), None)

    def retrieval_step(params, batch, cand):
        return dcn.retrieval_scores(params, cfg, batch, offsets, cand)

    flops = _dcn_flops(cfg, B, fwd_only=True) + 2.0 * B * nc * cfg.retrieval_dim
    return Cell(
        spec.arch_id, shape.name, "retrieval", retrieval_step,
        (params, batch, cand), (pshard, bshard, cand_shard), flops,
        notes=f"B={B} x {nc} candidates, batched dot + top_k",
    )


# =========================================================================
# paper engine (the paper's own contribution at published graph scale)
# =========================================================================

def _paper_cell(spec, shape, mesh, multi_pod,
                state_layout: str | None = None,
                or_impl: str | None = None) -> Cell:
    cfg = spec.full_config()
    dims = shape.dims
    n, avg_deg = dims["n_nodes"], dims["avg_degree"]
    sa = ("pod", "data") if multi_pod else ("data",)
    ga = ("model",)
    or_impl = or_impl or cfg.or_impl
    policy = POLICIES[cfg.policy](
        source_axes=sa, graph_axes=ga, or_impl=or_impl
    )
    shards = _axes_size(mesh, ga)
    n_pad = padded_n(n, shards, block=32)
    max_deg = cfg.max_deg_cap
    # memory-driven default: replicated per-node state for a 64-lane morsel
    # is 3·64 B/node (paper §4.2: 24 B packed; unpacked-lane tensor layout
    # trades 8x memory for MXU-shaped compute) — beyond ~40M nodes that
    # exceeds a 16 GB chip, switch to the sharded-state engine.
    if state_layout is None:
        lanes = policy.lanes if policy.is_multi_source else 1
        repl_bytes = n_pad * (3 * lanes + 4 * lanes)  # state + contribution
        state_layout = "sharded" if repl_bytes > 8e9 else "replicated"
    engine = build_engine(
        mesh, policy, cfg.edge_compute, n_pad, cfg.max_iters,
        state_layout=state_layout,
    )
    graph = EllGraph(
        indices=sds((n_pad, max_deg), jnp.int32),
        degrees=sds((n_pad,), jnp.int32),
        weights=None,
    )
    src_shards = _axes_size(mesh, sa)
    morsels_np = pad_sources(
        np.arange(cfg.n_sources, dtype=np.int32), src_shards,
        policy.lanes, n_pad,
    )
    morsels = sds(morsels_np.shape, jnp.int32)
    lanes = policy.lanes
    # useful work: one edge visit per lane per scanned edge per iteration —
    # expected iterations ~ BFS diameter (cfg.max_iters caps it)
    edges_scanned = n * min(avg_deg, max_deg)
    flops = 2.0 * edges_scanned * lanes
    return Cell(
        spec.arch_id, f"{shape.name}", "query", engine.fn,
        (graph, morsels), None, flops,
        iters_scale=float(cfg.max_iters),
        notes=(
            f"policy={policy.name} or={or_impl} state={state_layout} "
            f"lanes={lanes} n_pad={n_pad} max_deg={max_deg}"
        ),
        prejitted=True,
    )


# =========================================================================
# entry point
# =========================================================================

def build_cell(arch_id: str, shape_name: str, mesh: Mesh, multi_pod: bool,
               **overrides) -> Cell:
    from ..models.gnn import common as gnn_common

    gnn_common.set_edge_slabs(None)  # GNN builders re-enable per mesh
    spec = cfgbase.get(arch_id)
    shape = {s.name: s for s in spec.shapes}[shape_name]
    if shape_name in spec.skips:
        raise ValueError(
            f"{arch_id} x {shape_name} is a documented skip: "
            f"{spec.skips[shape_name]}"
        )
    if spec.family == "lm":
        return _lm_cell(spec, shape, mesh, multi_pod)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh, multi_pod)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, mesh, multi_pod)
    if spec.family == "paper":
        return _paper_cell(spec, shape, mesh, multi_pod, **overrides)
    raise ValueError(spec.family)


def lower_cell(cell: Cell, mesh: Mesh):
    """Lower (but do not compile) a cell under the mesh context."""
    if cell.prejitted:
        jf = cell.fn
    else:
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        jf = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate,
            **kw,
        )
    with mesh_context(mesh):
        return jf.lower(*cell.args)
