"""Recursive-query serving driver — the paper-kind end-to-end example.

A resident query service: the graph is loaded and ELL-partitioned once,
engines are compiled per (policy × edge-compute) and reused across request
batches (the paper's IFETask with a warm buffer pool). Each request batch
is a set of source nodes + an output kind (lengths histogram or actual
paths); the dispatcher picks the policy by the paper's robustness rule
(``recommend_policy``) unless pinned.

    PYTHONPATH=src python -m repro.launch.serve --dataset ldbc \
        --batches 20 --sources-per-batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core import (
    POLICIES,
    build_engine,
    histogram_lengths,
    pad_sources,
    prepare_graph,
    recommend_policy,
    reconstruct_paths,
)
from ..core.dispatcher import _axes_size
from ..graph.generators import PAPER_DATASETS, pick_sources


class QueryService:
    """Compile-once, serve-many recursive query engine pool."""

    def __init__(self, mesh, csr, max_deg=None, max_iters=64):
        self.mesh = mesh
        self.csr = csr
        self.max_iters = max_iters
        self._graphs = {}  # policy graph axes -> (EllGraph, n_pad)
        self._engines = {}  # (policy name, or_impl, ec, layout) -> engine
        self.max_deg = max_deg

    def _graph_for(self, policy):
        key = policy.graph_axes
        if key not in self._graphs:
            self._graphs[key] = prepare_graph(
                self.csr, self.mesh, policy, self.max_deg
            )
        return self._graphs[key]

    def _engine_for(self, policy, edge_compute, n_pad, layout):
        key = (policy.name, policy.or_impl, edge_compute, layout)
        if key not in self._engines:
            self._engines[key] = build_engine(
                self.mesh, policy, edge_compute, n_pad, self.max_iters,
                state_layout=layout,
            )
        return self._engines[key]

    def query(self, sources, returns_paths=False, policy=None,
              state_layout="replicated"):
        """One request batch -> (result state, policy used)."""
        n_sources = len(sources)
        name = policy or recommend_policy(
            n_sources,
            self.mesh.size,
            self.csr.avg_degree,
            returns_paths=returns_paths,
            n_nodes=self.csr.n_nodes,
        )
        pol = POLICIES[name]()
        if pol.is_multi_source:
            ec = "msbfs_parents" if returns_paths else "msbfs_lengths"
        else:
            ec = "sp_parents" if returns_paths else "sp_lengths"
        g, n_pad = self._graph_for(pol)
        engine = self._engine_for(pol, ec, n_pad, state_layout)
        morsels = pad_sources(
            np.asarray(sources, np.int32),
            _axes_size(self.mesh, pol.source_axes),
            pol.lanes,
            n_pad,
        )
        res = engine(g, jax.numpy.asarray(morsels))
        return res, name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ldbc",
                    choices=sorted(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--sources-per-batch", type=int, default=8)
    ap.add_argument("--paths", action="store_true",
                    help="return actual paths (parents), not lengths")
    ap.add_argument("--policy", default=None,
                    choices=(None, "1t1s", "nt1s", "ntks", "ntkms"))
    args = ap.parse_args(argv)

    csr = PAPER_DATASETS[args.dataset](args.scale)
    mesh = jax.make_mesh(
        (1, jax.device_count()), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    svc = QueryService(mesh, csr)
    print(
        f"serving {args.dataset} proxy: {csr.n_nodes} nodes, "
        f"{csr.n_edges} edges, avg degree {csr.avg_degree:.0f}"
    )

    rng = np.random.default_rng(0)
    lat, used = [], {}
    for b in range(args.batches):
        sources = pick_sources(
            csr, args.sources_per_batch, seed=100 + b
        )
        t0 = time.perf_counter()
        res, pol = svc.query(sources, returns_paths=args.paths,
                             policy=args.policy)
        if args.paths and not pol.startswith("ntkms"):
            dests = rng.integers(0, csr.n_nodes, 4).astype(np.int32)
            paths = reconstruct_paths(
                res.state.parents[0, : csr.n_nodes], dests, max_len=32
            )
            jax.block_until_ready(paths)
        else:
            hist = histogram_lengths(res.state.levels)
            jax.block_until_ready(hist)
        dt = (time.perf_counter() - t0) * 1e3
        lat.append(dt)
        used[pol] = used.get(pol, 0) + 1
        if b < 3 or b == args.batches - 1:
            print(f"batch {b:3d}: {len(sources)} sources -> {pol:6s} "
                  f"{dt:8.1f} ms")
    lat = np.asarray(lat)
    print(
        f"served {args.batches} batches: policies {used}; "
        f"p50 {np.percentile(lat, 50):.1f} ms, "
        f"p99 {np.percentile(lat, 99):.1f} ms "
        f"(first batch includes compile)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
