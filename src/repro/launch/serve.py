"""Recursive-query serving driver — the paper-kind end-to-end example.

A resident query service backed by the layered serving core (see
docs/serving.md): the graph is loaded and ELL-partitioned once, engines
are compiled per (kind × policy × edge-compute) into a shared cache and
reused across request batches, and each batch executes as the paper's
hybrid — phase 1 issues source-level morsels with per-shard convergence,
phase 2 re-dispatches stragglers at the frontier level — with policy and
scan layout picked per batch (``recommend_policy``/``recommend_backend``)
and the online learners (per-bucket phase-1 budgets, in-flight direction-
threshold refits) feeding on the served stream.

Two drivers share that core:

- **Open loop** (the default): an ``runtime.service.ServingLoop`` serves a
  seeded Poisson arrival stream — queries are admitted when they arrive
  whether or not the loop is keeping up, multi-tenant, optionally with
  per-query deadlines (``--deadline-ms``) and tenant quotas (``--quota``).
  Batch i's host-side result materialization overlaps batch i+1's device
  work (``--no-overlap`` pins the strictly serial baseline). Reported:
  per-tenant p50/p99, overlap occupancy, shed/deadline-miss counts.
- **Closed loop** (``--closed-loop``, or implied by ``--paths``): the
  legacy one-batch-at-a-time driver over ``AdaptiveScheduler.query``.

Both report *warm* latency percentiles — batches that compiled a new
engine (cache-miss batches) are excluded from p50/p99 and their wall is
reported separately as cold-start time, so the serving tail is never
conflated with compile time.

    PYTHONPATH=src python -m repro.launch.serve --dataset ldbc \
        --rate 20 --arrivals 60 --sources-per-batch 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..core import histogram_lengths, reconstruct_paths
from ..graph.delta import apply_delta_csr, random_delta
from ..graph.generators import (
    PAPER_DATASET_FAMILIES,
    PAPER_DATASETS,
    pick_sources,
)
from ..runtime.scheduler import AdaptiveScheduler
from ..runtime.service import ServingLoop
from .mesh import make_mesh


class QueryService:
    """Compile-once, serve-many recursive query engine pool.

    Thin façade over AdaptiveScheduler kept for API stability: ``query``
    returns ``(IFEResult, policy_name)`` like the original static service,
    while the scheduler underneath decides static vs two-phase execution.
    Callers that count or inspect compiles use the scheduler's public
    ``EngineCache`` surface (``len(svc.scheduler.cache)``, ``.keys()``,
    ``.items()``) — there is no private reach-through here.
    """

    def __init__(self, mesh, csr, max_deg=None, max_iters=64, adaptive=True,
                 backend="recommend", direction_thresholds=None, family=None,
                 online_adapt=True, refit_every=16, cost="auto"):
        self.mesh = mesh
        self.csr = csr
        self.max_iters = max_iters
        self.max_deg = max_deg
        self.scheduler = AdaptiveScheduler(
            mesh, csr, max_deg=max_deg, max_iters=max_iters,
            adaptive=adaptive, backend=backend,
            direction_thresholds=direction_thresholds, family=family,
            online_adapt=online_adapt, refit_every=refit_every, cost=cost,
        )
        self.last_outcome = None  # per-phase latency of the last query

    def query(self, sources, returns_paths=False, policy=None,
              state_layout="replicated", backend=None, query_kind="reach"):
        """One request batch -> (result state, policy used)."""
        out = self.scheduler.query(
            sources, returns_paths=returns_paths, policy=policy,
            state_layout=state_layout, backend=backend,
            query_kind=query_kind,
        )
        self.last_outcome = out
        return out.result, out.policy


def _pct(values, p):
    return np.percentile(np.asarray(values), p) if len(values) else float("nan")


def poisson_arrivals(csr, rate_qps: float, n_arrivals: int,
                     sources_per_query: int, tenants: int = 1,
                     deadline_ms: float | None = None, seed: int = 0,
                     query_kind: str = "reach"):
    """Seeded open-loop Poisson schedule for ``ServingLoop.run_stream``:
    exponential inter-arrival gaps at ``rate_qps``, tenants round-robin,
    every query's sources drawn by the same ``pick_sources`` rule the
    closed-loop driver uses (so the two drivers serve the same work)."""
    rng = np.random.default_rng(seed)
    gaps_ms = rng.exponential(1e3 / rate_qps, size=n_arrivals)
    t_ms = np.cumsum(gaps_ms)
    return [
        {
            "t_ms": float(t_ms[i]),
            "sources": pick_sources(csr, sources_per_query, seed=100 + i),
            "tenant": f"t{i % tenants}",
            "deadline_ms": deadline_ms,
            "query_kind": query_kind,
        }
        for i in range(n_arrivals)
    ]


def _report_core(sched, used=None) -> None:
    cache, stats = sched.cache, sched.stats
    if used:
        print(f"policies used: {used}")
    print(
        f"engine cache {len(cache)} compiled, "
        f"{cache.hits} hits / {cache.misses} misses "
        f"({dict(cache.misses_by_kind)} compiles by kind)"
    )
    print(
        f"phase-2 resume: {stats.resumed_ganged} survivor(s) ganged across "
        f"{stats.gangs} gang dispatch(es) "
        f"(occupancy {stats.gang_occupancy:.2f}), "
        f"{stats.resumed_serial} resumed serially"
    )
    if sched.budget_model is not None:
        model = sched.budget_model
        budgets = {
            f"{fam}/2^{b}": v
            for (fam, b), v in model.budgets(sched.max_iters).items()
        }
        mp = model.mispredicts
        print(
            f"online adapt: {stats.refits} threshold refit(s) from "
            f"{sum(len(r) for r in sched._dir_samples.values())} live "
            f"samples; learned budgets {budgets}; "
            f"budget mispredicts {mp.too_low} too-low / {mp.too_high} "
            f"too-high over {mp.observed} morsels "
            f"(rate {stats.budget_mispredict_rate:.3f}, "
            f"{stats.budget_inert_slots} inert budget slots)"
        )


def run_open_loop(args, csr, mesh, family) -> int:
    loop = ServingLoop(
        mesh, csr, adaptive=not args.static, backend=args.backend,
        direction_thresholds=args.thresholds, family=family,
        online_adapt=args.online_adapt, refit_every=args.refit_every,
        overlap=args.overlap, tenant_quota=args.quota,
        max_batch_sources=args.max_batch_sources, cost=args.cost_mode,
    )
    arrivals = poisson_arrivals(
        csr, args.rate, args.arrivals, args.sources_per_batch,
        tenants=args.tenants, deadline_ms=args.deadline_ms, seed=1,
        query_kind=args.query_kind,
    )
    if args.mutate_stream:
        # interleave seeded edge-edit batches evenly through the arrival
        # schedule; run_stream applies each through the serving fence
        # (admitted-before sees the old graph, admitted-after the new)
        span = arrivals[-1]["t_ms"] if arrivals else 0.0
        cur = csr
        for i in range(args.mutate_stream):
            t_ms = span * (i + 1) / (args.mutate_stream + 1)
            d = random_delta(cur, args.delta_edges, args.delta_edges,
                             seed=500 + i)
            cur = apply_delta_csr(cur, d)  # deletes sample the live graph
            arrivals.append({"t_ms": float(t_ms), "delta": d})
        arrivals.sort(key=lambda a: a["t_ms"])
    print(
        f"open loop: {args.arrivals} Poisson arrivals at {args.rate:.1f} "
        f"q/s across {args.tenants} tenant(s)"
        + (f", deadline {args.deadline_ms:.0f} ms" if args.deadline_ms else "")
        + (f", {args.mutate_stream} interleaved graph delta(s) of "
           f"±{args.delta_edges} edges" if args.mutate_stream else "")
    )
    t0 = time.perf_counter()
    loop.run_stream(arrivals)
    wall_s = time.perf_counter() - t0
    st = loop.stats
    print(
        f"served {st.completed} queries in {wall_s:.2f} s over "
        f"{st.batches} batches ({st.cold_batches} cold); "
        f"warm p50 {st.p50():.1f} ms, p99 {st.p99():.1f} ms "
        f"(all-in p50 {st.p50(warm=False):.1f} ms, "
        f"p99 {st.p99(warm=False):.1f} ms); "
        f"cold-start {st.cold_ms:.0f} ms excluded from warm percentiles"
    )
    print(
        f"overlap occupancy {st.overlap_occupancy:.2f} "
        f"({st.overlapped_finalizes}/{st.finalizes} finalizes hidden "
        f"behind device work); shed {st.shed}, "
        f"deadline misses {st.deadline_misses}, "
        f"evictions {loop.admission.stats.evictions}"
    )
    for name in sorted(st.tenants):
        ts = st.tenants[name]
        print(
            f"  tenant {name}: {ts.completed}/{ts.submitted} served, "
            f"warm p50 {ts.p50():.1f} ms p99 {ts.p99():.1f} ms, "
            f"shed {ts.shed}, misses {ts.deadline_misses}"
        )
    if st.deltas_applied:
        same = sum(1 for r in loop.delta_reports if r.same_shape)
        inval = sum(r.engines_invalidated for r in loop.delta_reports)
        print(
            f"graph deltas: {st.deltas_applied} applied "
            f"(now version {loop.graph_version}); {same} kept every "
            f"operand shape (engines stayed warm), "
            f"{inval} engine(s) invalidated by reshapes; final graph "
            f"{loop.dispatcher.csr.n_edges} edges"
        )
    _report_core(loop.dispatcher)
    return 0


def run_closed_loop(args, csr, mesh, family) -> int:
    svc = QueryService(mesh, csr, adaptive=not args.static,
                       backend=args.backend,
                       direction_thresholds=args.thresholds, family=family,
                       online_adapt=args.online_adapt,
                       refit_every=args.refit_every, cost=args.cost_mode)
    rng = np.random.default_rng(0)
    lat, warm_lat, p1_ms, p2_ms, used = [], [], [], [], {}
    redispatched, cold_ms = 0, 0.0
    cache = svc.scheduler.cache
    for b in range(args.batches):
        sources = pick_sources(
            csr, args.sources_per_batch, seed=100 + b
        )
        compiles0 = cache.compile_events
        t0 = time.perf_counter()
        res, pol = svc.query(sources, returns_paths=args.paths,
                             policy=args.policy,
                             query_kind=args.query_kind)
        if args.query_kind != "reach":
            # non-reach kinds carry their own result leaves (dists /
            # mass / wedges+closed): sync the whole state for timing
            jax.block_until_ready(res.state)
        elif args.paths and not pol.startswith("ntkms"):
            dests = rng.integers(0, csr.n_nodes, 4).astype(np.int32)
            paths = reconstruct_paths(
                res.state.parents[0, : csr.n_nodes], dests, max_len=32
            )
            jax.block_until_ready(paths)
        else:
            hist = histogram_lengths(res.state.levels)
            jax.block_until_ready(hist)
        dt = (time.perf_counter() - t0) * 1e3
        lat.append(dt)
        if cache.compile_events > compiles0:  # this batch paid a compile
            cold_ms += dt
        else:
            warm_lat.append(dt)
        used[pol] = used.get(pol, 0) + 1
        out = svc.last_outcome
        p1_ms.append(out.phase_ms["phase1"])
        p2_ms.append(out.phase_ms["phase2"])
        redispatched += out.redispatched
        if b < 3 or b == args.batches - 1:
            phase = (
                f"p1 {out.phase_ms['phase1']:7.1f} ms"
                f" p2 {out.phase_ms['phase2']:7.1f} ms"
                if out.hybrid else "static"
            )
            print(f"batch {b:3d}: {len(sources)} sources -> {pol:6s} "
                  f"{dt:8.1f} ms  [{phase}]")
    p1_ms, p2_ms = map(np.asarray, (p1_ms, p2_ms))
    print(
        f"served {args.batches} batches ({args.batches - len(warm_lat)} "
        f"cold): warm p50 {_pct(warm_lat, 50):.1f} ms, "
        f"p99 {_pct(warm_lat, 99):.1f} ms "
        f"(all-in p50 {_pct(lat, 50):.1f} ms, p99 {_pct(lat, 99):.1f} ms); "
        f"cold-start {cold_ms:.0f} ms excluded from warm percentiles"
    )
    print(
        f"phase1 p50/p99 {np.percentile(p1_ms, 50):.1f}/"
        f"{np.percentile(p1_ms, 99):.1f} ms; "
        f"phase2 p50/p99 {np.percentile(p2_ms, 50):.1f}/"
        f"{np.percentile(p2_ms, 99):.1f} ms; "
        f"{redispatched} morsels re-dispatched"
    )
    _report_core(svc.scheduler, used)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ldbc",
                    choices=sorted(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--closed-loop", action="store_true",
                    help="legacy one-batch-at-a-time driver (implied by "
                         "--paths); default is the open-loop ServingLoop")
    ap.add_argument("--batches", type=int, default=20,
                    help="closed-loop request batches")
    ap.add_argument("--arrivals", type=int, default=60,
                    help="open-loop arrival count")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="open-loop Poisson arrival rate (queries/sec)")
    ap.add_argument("--tenants", type=int, default=2,
                    help="open-loop tenant count (round-robin arrivals)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query SLO deadline; enables deadline-aware "
                         "pack eviction and load shedding")
    ap.add_argument("--quota", type=int, default=None,
                    help="max concurrent queries per tenant (over-quota "
                         "submissions are shed)")
    ap.add_argument("--overlap", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="overlap batch i's host materialization with "
                         "batch i+1's device work (--no-overlap is the "
                         "strictly serial baseline)")
    ap.add_argument("--sources-per-batch", type=int, default=8)
    ap.add_argument("--max-batch-sources", type=int, default=None,
                    help="bound one batch's pooled sources (open loop): "
                         "under backlog the queue drains as capped "
                         "batches with re-admission between them, keeping "
                         "tail latency at O(batch) instead of O(backlog)")
    ap.add_argument("--mutate-stream", type=int, default=0, metavar="N",
                    help="open loop: interleave N seeded graph deltas "
                         "evenly through the arrival schedule; each is "
                         "applied through the serving fence (in-flight "
                         "batches finish on the old graph, later "
                         "admissions see the new one)")
    ap.add_argument("--delta-edges", type=int, default=64, metavar="M",
                    help="edges added and deleted per --mutate-stream "
                         "delta")
    ap.add_argument("--query-kind", default="reach",
                    choices=("reach", "topk_paths", "ppr", "pattern_counts"),
                    help="scenario family served by every arrival/batch: "
                         "'reach' = BFS levels (the historical surface), "
                         "'topk_paths' = weighted k-shortest distances "
                         "(synthesizes seeded edge weights when the "
                         "dataset has none), 'ppr' = personalized "
                         "PageRank mass, 'pattern_counts' = 2/3-hop "
                         "wedge+triangle walk counts; non-reach kinds "
                         "are never lane-packed")
    ap.add_argument("--paths", action="store_true",
                    help="return actual paths (parents), not lengths "
                         "(closed loop only)")
    ap.add_argument("--policy", default=None,
                    choices=(None, "1t1s", "nt1s", "ntks", "ntkms"))
    ap.add_argument("--backend", default="recommend",
                    choices=("ell_push", "ell_pull", "pull_binned",
                             "pull_binned_fused", "block_mxu", "dopt",
                             "dopt_ell", "dopt_binned", "dopt_fused",
                             "recommend"),
                    help="frontier-extension backend; the default "
                         "'recommend' picks the scan layout per batch via "
                         "recommend_backend (direction-optimized binned "
                         "pull for the BFS family) — all choices are "
                         "bit-identical in results")
    ap.add_argument("--thresholds", default=None, metavar="BENCH_JSON",
                    help="fit the direction switch's alpha/beta from this "
                         "BENCH_direction_opt.json trace file "
                         "(core.policies.fit_direction_thresholds) instead "
                         "of Beamer's constants; an explicit table is a "
                         "PIN — online refitting will not replace it")
    ap.add_argument("--static", action="store_true",
                    help="disable the adaptive hybrid (static dispatch)")
    ap.add_argument("--online-adapt", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="online policy learning: per-(family, "
                         "source-degree-bucket) phase-1 budget model + "
                         "in-flight direction-threshold refitting from the "
                         "live per-iteration sample tap "
                         "(--no-online-adapt pins the legacy global-p90 "
                         "budget and static thresholds)")
    ap.add_argument("--refit-every", type=int, default=16,
                    help="batches between in-flight threshold refits")
    ap.add_argument("--cost-mode", default="auto",
                    choices=("auto", "slots", "measured"),
                    help="direction-threshold fit cost model: 'slots' "
                         "scores by scan-slot counts (deterministic); "
                         "'measured' converts slots to wall-ms via the "
                         "lazily-probed per-backend rates "
                         "(core.extend.BackendCostProbe); 'auto' picks "
                         "measured on TPU, slots on CPU/interpret")
    args = ap.parse_args(argv)

    csr = PAPER_DATASETS[args.dataset](args.scale)
    if args.query_kind == "topk_paths" and csr.weights is None:
        # the k-shortest relax needs edge weights; paper proxy datasets
        # are unweighted, so synthesize a seeded uniform weighting (the
        # same convention as the weighted-graph test corpus)
        rng = np.random.default_rng(7)
        csr = dataclasses.replace(
            csr,
            weights=rng.uniform(0.1, 2.0, csr.n_edges).astype(np.float32),
        )
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    # threshold-table family of the dataset (None => Beamer-default /
    # nearest-bucket fallback inside DirectionThresholds.lookup)
    family = PAPER_DATASET_FAMILIES.get(args.dataset)
    print(
        f"serving {args.dataset} proxy: {csr.n_nodes} nodes, "
        f"{csr.n_edges} edges, avg degree {csr.avg_degree:.0f}"
    )
    if args.closed_loop or args.paths:
        return run_closed_loop(args, csr, mesh, family)
    return run_open_loop(args, csr, mesh, family)


if __name__ == "__main__":
    raise SystemExit(main())
