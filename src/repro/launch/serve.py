"""Recursive-query serving driver — the paper-kind end-to-end example.

A resident query service backed by the adaptive morsel runtime
(repro.runtime.scheduler): the graph is loaded and ELL-partitioned once,
engines are compiled per (kind × policy × edge-compute) into a shared cache
and reused across request batches, and each batch executes as the paper's
hybrid — phase 1 issues source-level morsels with per-shard convergence,
phase 2 re-dispatches stragglers at the frontier level — with the policy
picked per batch by the paper's robustness rule (``recommend_policy``)
unless pinned, and the frontier-extension scan layout picked by
``recommend_backend`` (the default: direction-optimized degree-binned
pull; ``--thresholds`` swaps Beamer's alpha/beta for constants fitted
from ``BENCH_direction_opt.json`` traces). With ``--online-adapt`` (the
default) the runtime also learns from the stream it serves: the phase-1
budget comes from the per-(family, source-degree-bucket) BudgetModel and
the direction thresholds are refit in-flight from the live sample tap.
The driver reports per-phase latency percentiles plus the learner's
refit/mispredict counters so the hybrid's split and the policy loop's
accuracy are observable in serving terms.

    PYTHONPATH=src python -m repro.launch.serve --dataset ldbc \
        --batches 20 --sources-per-batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core import histogram_lengths, reconstruct_paths
from ..graph.generators import (
    PAPER_DATASET_FAMILIES,
    PAPER_DATASETS,
    pick_sources,
)
from ..runtime.scheduler import AdaptiveScheduler
from .mesh import make_mesh


class QueryService:
    """Compile-once, serve-many recursive query engine pool.

    Thin façade over AdaptiveScheduler kept for API stability: ``query``
    returns ``(IFEResult, policy_name)`` like the original static service,
    while the scheduler underneath decides static vs two-phase execution.
    """

    def __init__(self, mesh, csr, max_deg=None, max_iters=64, adaptive=True,
                 backend="recommend", direction_thresholds=None, family=None,
                 online_adapt=True, refit_every=16):
        self.mesh = mesh
        self.csr = csr
        self.max_iters = max_iters
        self.max_deg = max_deg
        self.scheduler = AdaptiveScheduler(
            mesh, csr, max_deg=max_deg, max_iters=max_iters,
            adaptive=adaptive, backend=backend,
            direction_thresholds=direction_thresholds, family=family,
            online_adapt=online_adapt, refit_every=refit_every,
        )
        self.last_outcome = None  # per-phase latency of the last query

    @property
    def _engines(self):
        """Engine-cache view (kept for callers/tests counting compiles)."""
        return self.scheduler.cache._engines

    def query(self, sources, returns_paths=False, policy=None,
              state_layout="replicated", backend=None):
        """One request batch -> (result state, policy used)."""
        out = self.scheduler.query(
            sources, returns_paths=returns_paths, policy=policy,
            state_layout=state_layout, backend=backend,
        )
        self.last_outcome = out
        return out.result, out.policy


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ldbc",
                    choices=sorted(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--sources-per-batch", type=int, default=8)
    ap.add_argument("--paths", action="store_true",
                    help="return actual paths (parents), not lengths")
    ap.add_argument("--policy", default=None,
                    choices=(None, "1t1s", "nt1s", "ntks", "ntkms"))
    ap.add_argument("--backend", default="recommend",
                    choices=("ell_push", "ell_pull", "pull_binned",
                             "block_mxu", "dopt", "dopt_ell", "dopt_binned",
                             "recommend"),
                    help="frontier-extension backend; the default "
                         "'recommend' picks the scan layout per batch via "
                         "recommend_backend (direction-optimized binned "
                         "pull for the BFS family) — all choices are "
                         "bit-identical in results")
    ap.add_argument("--thresholds", default=None, metavar="BENCH_JSON",
                    help="fit the direction switch's alpha/beta from this "
                         "BENCH_direction_opt.json trace file "
                         "(core.policies.fit_direction_thresholds) instead "
                         "of Beamer's constants; an explicit table is a "
                         "PIN — online refitting will not replace it")
    ap.add_argument("--static", action="store_true",
                    help="disable the adaptive hybrid (static dispatch)")
    ap.add_argument("--online-adapt", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="online policy learning: per-(family, "
                         "source-degree-bucket) phase-1 budget model + "
                         "in-flight direction-threshold refitting from the "
                         "live per-iteration sample tap "
                         "(--no-online-adapt pins the legacy global-p90 "
                         "budget and static thresholds)")
    ap.add_argument("--refit-every", type=int, default=16,
                    help="batches between in-flight threshold refits")
    args = ap.parse_args(argv)

    csr = PAPER_DATASETS[args.dataset](args.scale)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    # threshold-table family of the dataset (None => Beamer-default /
    # nearest-bucket fallback inside DirectionThresholds.lookup)
    family = PAPER_DATASET_FAMILIES.get(args.dataset)
    svc = QueryService(mesh, csr, adaptive=not args.static,
                       backend=args.backend,
                       direction_thresholds=args.thresholds, family=family,
                       online_adapt=args.online_adapt,
                       refit_every=args.refit_every)
    print(
        f"serving {args.dataset} proxy: {csr.n_nodes} nodes, "
        f"{csr.n_edges} edges, avg degree {csr.avg_degree:.0f}"
    )

    rng = np.random.default_rng(0)
    lat, p1_ms, p2_ms, used = [], [], [], {}
    redispatched = 0
    for b in range(args.batches):
        sources = pick_sources(
            csr, args.sources_per_batch, seed=100 + b
        )
        t0 = time.perf_counter()
        res, pol = svc.query(sources, returns_paths=args.paths,
                             policy=args.policy)
        if args.paths and not pol.startswith("ntkms"):
            dests = rng.integers(0, csr.n_nodes, 4).astype(np.int32)
            paths = reconstruct_paths(
                res.state.parents[0, : csr.n_nodes], dests, max_len=32
            )
            jax.block_until_ready(paths)
        else:
            hist = histogram_lengths(res.state.levels)
            jax.block_until_ready(hist)
        dt = (time.perf_counter() - t0) * 1e3
        lat.append(dt)
        used[pol] = used.get(pol, 0) + 1
        out = svc.last_outcome
        p1_ms.append(out.phase_ms["phase1"])
        p2_ms.append(out.phase_ms["phase2"])
        redispatched += out.redispatched
        if b < 3 or b == args.batches - 1:
            phase = (
                f"p1 {out.phase_ms['phase1']:7.1f} ms"
                f" p2 {out.phase_ms['phase2']:7.1f} ms"
                if out.hybrid else "static"
            )
            print(f"batch {b:3d}: {len(sources)} sources -> {pol:6s} "
                  f"{dt:8.1f} ms  [{phase}]")
    lat, p1_ms, p2_ms = map(np.asarray, (lat, p1_ms, p2_ms))
    cache = svc.scheduler.cache
    stats = svc.scheduler.stats
    print(
        f"served {args.batches} batches: policies {used}; "
        f"p50 {np.percentile(lat, 50):.1f} ms, "
        f"p99 {np.percentile(lat, 99):.1f} ms "
        f"(first batch includes compile)"
    )
    print(
        f"phase1 p50/p99 {np.percentile(p1_ms, 50):.1f}/"
        f"{np.percentile(p1_ms, 99):.1f} ms; "
        f"phase2 p50/p99 {np.percentile(p2_ms, 50):.1f}/"
        f"{np.percentile(p2_ms, 99):.1f} ms; "
        f"{redispatched} morsels re-dispatched; "
        f"engine cache {len(cache)} compiled, "
        f"{cache.hits} hits / {cache.misses} misses "
        f"({dict(cache.misses_by_kind)} compiles by kind)"
    )
    print(
        f"phase-2 resume: {stats.resumed_ganged} survivor(s) ganged across "
        f"{stats.gangs} gang dispatch(es) "
        f"(occupancy {stats.gang_occupancy:.2f}), "
        f"{stats.resumed_serial} resumed serially"
    )
    if args.online_adapt:
        sched = svc.scheduler
        model = sched.budget_model
        budgets = {
            f"{fam}/2^{b}": v
            for (fam, b), v in model.budgets(sched.max_iters).items()
        }
        mp = model.mispredicts
        print(
            f"online adapt: {stats.refits} threshold refit(s) from "
            f"{sum(len(r) for r in sched._dir_samples.values())} live "
            f"samples; learned budgets {budgets}; "
            f"budget mispredicts {mp.too_low} too-low / {mp.too_high} "
            f"too-high over {mp.observed} morsels "
            f"(rate {stats.budget_mispredict_rate:.3f}, "
            f"{stats.budget_inert_slots} inert budget slots)"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
