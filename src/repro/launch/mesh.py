"""Production mesh construction + version-compatible ``make_mesh``.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run forces 512 host devices before first jax init;
real deployments get the same mesh over ICI-connected TPU chips.

Single-pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the pod axis
extends data parallelism across pods (DCN-crossing collectives are gradient
all-reduces only; frontier/TP collectives stay inside a pod).

Supported jax range: 0.4.35 — 0.8.x. ``jax.sharding.AxisType`` and the
``axis_types=`` kwarg of ``jax.make_mesh`` only exist on the newer end of
that range; ``make_mesh`` below passes them exactly when available, so every
mesh in the repo (prod, tests, benchmarks) is built through one helper.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Version-compatible ``jax.make_mesh(..., axis_types=Auto)``.

    On jax with ``jax.sharding.AxisType`` the mesh is built with explicit
    Auto axis types (required for shard_map+auto-sharding interop there);
    on jax 0.4.x — where the kwarg does not exist and all axes are
    implicitly Auto — the plain two-argument form is used.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
            )
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def batch_axes(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def all_axes(multi_pod: bool = False):
    return ("pod", "data", "model") if multi_pod else ("data", "model")
