"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run forces 512 host devices before first jax init;
real deployments get the same mesh over ICI-connected TPU chips.

Single-pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the pod axis
extends data parallelism across pods (DCN-crossing collectives are gradient
all-reduces only; frontier/TP collectives stay inside a pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def batch_axes(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def all_axes(multi_pod: bool = False):
    return ("pod", "data", "model") if multi_pod else ("data", "model")
