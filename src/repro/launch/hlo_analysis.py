"""Roofline terms from a compiled XLA artifact (DESIGN.md §7).

``cost_analysis()`` gives per-device HLO FLOPs and bytes (XLA multiplies
while/scan bodies by known trip counts). Collective bytes are NOT in
cost_analysis — we parse the post-SPMD optimized HLO and sum operand sizes
of every collective op, weighting each kind by its ring wire factor:

    all-reduce          2·(K−1)/K · bytes     (reduce-scatter + all-gather)
    all-gather          (K−1)/K · out_bytes   (out is the gathered shape)
    reduce-scatter      (K−1)   · out_bytes   (in = K · out)
    all-to-all          (K−1)/K · bytes
    collective-permute  1 · bytes

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Caveat recorded with every report: XLA's HLO cost analysis counts a
*dynamic-trip-count* while body ONCE; the IFE query engine's frontier loop
is such a body, so its terms carry an explicit ``iters_scale`` multiplier
(expected iteration count). lax.scan layers (LM) have static trip counts
and are counted correctly (verified against 6·N·D).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "f32[128,1024]{1,0}" or "u32[16]"  (shape layout braces optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[N]
    m = _GROUPS_RE.search(line)
    if m:
        return max(m.group(1).count(",") + 1, 1)
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict  # kind -> op count
    out_bytes: dict  # kind -> sum of result bytes
    wire_bytes: dict  # kind -> ring-weighted bytes on the wire per device

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    out_bytes = {k: 0.0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        k = _group_size(line)
        counts[kind] += 1
        out_bytes[kind] += b
        if kind == "all-reduce":
            wire[kind] += 2.0 * (k - 1) / k * b
        elif kind == "all-gather":
            wire[kind] += (k - 1) / k * b
        elif kind == "reduce-scatter":
            wire[kind] += (k - 1) * b
        elif kind == "all-to-all":
            wire[kind] += (k - 1) / k * b
        else:  # collective-permute
            wire[kind] += b
    return CollectiveStats(counts=counts, out_bytes=out_bytes, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed
    wire_bytes: float  # per-device ring-weighted collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float
    iters_scale: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops_per_device / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline actually achieved if the step
        runs at the dominant-term rate: (useful flop time) / (bound time)."""
        ideal = self.model_flops_per_device / PEAK_FLOPS
        return ideal / max(self.bound_s, 1e-30)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "iters_scale": self.iters_scale,
        }


def roofline_terms(
    cost: dict,
    coll: CollectiveStats,
    n_devices: int,
    model_flops_total: float,
    iters_scale: float = 1.0,
) -> Roofline:
    flops = float(cost.get("flops", 0.0)) * iters_scale
    hbm = float(cost.get("bytes accessed", 0.0)) * iters_scale
    wire = coll.total_wire_bytes * iters_scale
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=wire,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=wire / ICI_BW,
        model_flops_per_device=model_flops_total / n_devices,
        iters_scale=iters_scale,
    )
