import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: XLA locks the
# host device count at first init, and the production meshes below need 512
# placeholder devices (2 pods x 16 x 16). Only the dry-run does this — smoke
# tests and benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) cell.

For each cell this prints/records:
- ``compiled.memory_analysis()``  — proves the cell fits per-device HBM;
- ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes;
- the collective schedule parsed from the optimized HLO (op counts, bytes);
- the three roofline terms (compute/memory/collective, seconds).

Results land in ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json`` —
``benchmarks/roofline.py`` and EXPERIMENTS.md read from there. Already-done
cells are skipped unless ``--force`` (the dry-run is resumable; this box has
one core and ~40 compiles to do).

Usage:
    python -m repro.launch.dryrun --list
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both [--subprocess]
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape: str, mesh_tag: str, out_dir: str,
             force: bool = False, tag: str = "",
             overrides: dict | None = None) -> dict:
    name = f"{arch}__{shape}__{mesh_tag}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        print(f"[skip] {name}: cached ({rec.get('status')})")
        return rec

    import jax  # deferred: XLA_FLAGS must already be set

    from .hlo_analysis import parse_collectives, roofline_terms
    from .mesh import make_production_mesh
    from .steps import build_cell, lower_cell

    multi_pod = mesh_tag == "multi"
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_tag,
        "status": "error", "tag": tag,
    }
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        cell = build_cell(arch, shape, mesh, multi_pod, **(overrides or {}))
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                mem_rec[f] = int(getattr(mem, f, 0))
            # aliased (donated) outputs live in the argument buffers
            mem_rec["total_bytes_per_device"] = (
                mem_rec.get("argument_size_in_bytes", 0)
                + mem_rec.get("output_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0)
                - mem_rec.get("alias_size_in_bytes", 0)
            )
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "optimal_seconds")}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rl = roofline_terms(
            cost, coll, n_dev, cell.model_flops, cell.iters_scale
        )
        rec.update(
            status="ok",
            kind=cell.kind,
            notes=cell.notes,
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_rec,
            cost=cost,
            collective_counts={k: v for k, v in coll.counts.items() if v},
            collective_out_bytes={
                k: v for k, v in coll.out_bytes.items() if v
            },
            collective_wire_bytes={
                k: v for k, v in coll.wire_bytes.items() if v
            },
            roofline=rl.as_dict(),
        )
        fit = mem_rec.get("total_bytes_per_device", 0) <= 16 * 2**30
        rec["fits_16g_hbm"] = bool(fit)
        print(
            f"[ok]   {name}: compile {t_compile:.1f}s  "
            f"mem/dev {mem_rec.get('total_bytes_per_device', 0)/2**30:.2f} GiB"
            f"{'' if fit else ' (EXCEEDS 16G)'}  "
            f"flops/dev {rl.flops:.3e}  dominant={rl.dominant}  "
            f"terms c/m/x = {rl.compute_s:.2e}/{rl.memory_s:.2e}/"
            f"{rl.collective_s:.2e} s"
        )
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {name}: {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def run_components(arch: str, shape: str, mesh_tag: str, out_dir: str,
                   force: bool = False) -> dict:
    """Compositional roofline for LM cells (see steps.lm_components):
    sums trips x per-component terms — the correct accounting for programs
    whose hot loops XLA's cost analysis counts only once."""
    name = f"{arch}__{shape}__{mesh_tag}__comp"
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        print(f"[skip] {name}: cached ({rec.get('status')})")
        return rec

    import jax

    from .hlo_analysis import (
        HBM_BW, ICI_BW, PEAK_FLOPS, parse_collectives,
    )
    from .mesh import make_production_mesh
    from .steps import build_cell, lm_components, lower_cell

    multi_pod = mesh_tag == "multi"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
           "tag": "comp", "status": "error"}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mono = build_cell(arch, shape, mesh, multi_pod)
        comps = lm_components(arch, shape, mesh, multi_pod)
        total = {"flops": 0.0, "bytes": 0.0, "wire": 0.0}
        breakdown = []
        t0 = time.time()
        for c in comps:
            lowered = lower_cell(c, mesh)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, list) else cost
            coll = parse_collectives(compiled.as_text())
            f = float(cost.get("flops", 0.0)) * c.iters_scale
            b = float(cost.get("bytes accessed", 0.0)) * c.iters_scale
            w = coll.total_wire_bytes * c.iters_scale
            total["flops"] += f
            total["bytes"] += b
            total["wire"] += w
            breakdown.append({
                "component": c.notes, "trips": c.iters_scale,
                "flops": f, "bytes": b, "wire": w,
                "collectives": {k: v for k, v in coll.counts.items() if v},
            })
        terms = {
            "compute_s": total["flops"] / PEAK_FLOPS,
            "memory_s": total["bytes"] / HBM_BW,
            "collective_s": total["wire"] / ICI_BW,
        }
        dom = max(terms, key=terms.get).replace("_s", "")
        model_fpd = mono.model_flops / mesh.size
        bound = max(terms.values())
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            n_devices=mesh.size,
            components=breakdown,
            roofline={
                "flops_per_device": total["flops"],
                "hbm_bytes_per_device": total["bytes"],
                "wire_bytes_per_device": total["wire"],
                **terms,
                "dominant": dom,
                "model_flops_per_device": model_fpd,
                "useful_fraction": model_fpd / max(total["flops"], 1.0),
                "roofline_fraction": (model_fpd / PEAK_FLOPS)
                / max(bound, 1e-30),
                "iters_scale": 1.0,
            },
        )
        rl = rec["roofline"]
        print(
            f"[ok]   {name}: flops/dev {rl['flops_per_device']:.3e} "
            f"useful {rl['useful_fraction']:.2f} dominant={dom} "
            f"terms c/m/x = {terms['compute_s']:.2e}/"
            f"{terms['memory_s']:.2e}/{terms['collective_s']:.2e} s "
            f"roofline {rl['roofline_fraction']*100:.1f}%"
        )
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {name}: {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def iter_cells():
    # config registry import is jax-free
    from ..configs import base as cfgbase

    cells, skips = cfgbase.all_cells()
    return cells, skips


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag for perf sweeps")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each cell in a fresh process")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--override", action="append", default=[],
                    help="key=value cell overrides (paper cells)")
    ap.add_argument("--components", action="store_true",
                    help="compositional roofline for LM cells")
    args = ap.parse_args()

    cells, skips = iter_cells()
    if args.list:
        for a, s in cells:
            print(f"{a:28s} {s}")
        for a, s, why in skips:
            print(f"{a:28s} {s}  [SKIP: {why}]")
        return 0

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = v

    if args.all:
        todo = [(a, s, m) for a, s in cells for m in meshes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, mesh_tag in todo:
        if args.subprocess:
            import subprocess

            name = f"{arch}__{shape}__{mesh_tag}"
            path = os.path.join(
                args.out,
                name + (f"__{args.tag}" if args.tag else "") + ".json",
            )
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    rec = json.load(f)
                print(f"[skip] {name}: cached ({rec.get('status')})")
                failures += rec.get("status") != "ok"
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_tag,
                   "--out", args.out]
            if args.force:
                cmd.append("--force")
            if args.tag:
                cmd += ["--tag", args.tag]
            for kv in args.override:
                cmd += ["--override", kv]
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                failures += r.returncode != 0
            except subprocess.TimeoutExpired:
                print(f"[FAIL] {name}: timeout {args.timeout}s")
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": mesh_tag, "status": "error",
                               "error": f"timeout {args.timeout}s"}, f)
                failures += 1
        elif args.components:
            rec = run_components(arch, shape, mesh_tag, args.out,
                                 force=args.force)
            failures += rec.get("status") != "ok"
        else:
            rec = run_cell(arch, shape, mesh_tag, args.out,
                           force=args.force, tag=args.tag,
                           overrides=overrides)
            failures += rec.get("status") != "ok"
    print(f"done: {len(todo) - failures}/{len(todo)} ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
