"""Config-driven fault-tolerant training driver.

End-to-end: arch config -> model init -> sharded data stream -> jit train
step -> TrainGuard loop (checkpoint every N, crash-resume, straggler EWMA).
On a real pod the same script runs under ``jax.distributed.initialize()``;
on this box it drives the smoke-scale configs.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import CheckpointManager
from ..configs import base as cfgbase
from ..data.pipeline import TokenStream
from ..models import transformer as tfm
from ..nn.module import count_params, split_boxed
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.schedules import cosine_schedule, wsd_schedule
from ..runtime.fault_tolerance import StragglerDetector, TrainGuard


@dataclasses.dataclass
class TrainState:
    params: dict
    opt: object
    step: int = 0


def build(arch: str, smoke: bool, batch: int, seq: int, lr: float):
    spec = cfgbase.get(arch)
    assert spec.family == "lm", "train.py drives the LM family"
    cfg = spec.smoke_config() if smoke else spec.full_config()
    params, _ = split_boxed(tfm.init(jax.random.PRNGKey(0), cfg))
    ocfg = AdamWConfig(lr=lr)
    opt = adamw_init(params, ocfg)
    sched = (
        wsd_schedule(warmup=20, total=10_000)
        if spec.schedule == "wsd"
        else cosine_schedule(warmup=20, total=10_000)
    )
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq, global_batch=batch)

    @jax.jit
    def train_step(params, opt, batch, lr_scale):
        loss, grads = jax.value_and_grad(tfm.loss_fn)(params, cfg, batch)
        params, opt, gnorm = adamw_update(
            grads, opt, params, ocfg, lr_scale=lr_scale
        )
        return params, opt, loss, gnorm

    return cfg, params, opt, sched, stream, train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, params, opt, sched, stream, train_step = build(
        args.arch, args.smoke, args.batch, args.seq, args.lr
    )
    print(f"{cfg.name}: {count_params(params)/1e6:.1f}M params")
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    detector = StragglerDetector()
    guard = TrainGuard(
        ckpt=ckpt, save_every=args.save_every, detector=detector
    )

    # resume if a checkpoint exists (crash-restart path)
    state = {"params": params, "opt": opt}
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, start = ckpt.restore(state)[0], latest
        print(f"resumed from step {start}")

    losses = []

    def step_fn(state, step):
        batch = jax.tree.map(jnp.asarray, stream.batch(step))
        p, o, loss, gnorm = train_step(
            state["params"], state["opt"], batch, sched(step)
        )
        if step % args.log_every == 0:
            print(
                f"step {step:5d}  loss {float(loss):.4f}  "
                f"gnorm {float(gnorm):.3f}  lr x{sched(step):.3f}"
            )
        losses.append(float(loss))
        return {"params": p, "opt": o}

    t0 = time.time()
    state, end = guard.run(state, step_fn, args.steps, start_step=start)
    dt = time.time() - t0
    tok_s = (end - start) * args.batch * args.seq / max(dt, 1e-9)
    print(
        f"done: steps {start}->{end} in {dt:.1f}s ({tok_s:.0f} tok/s); "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
        f"stragglers flagged: {len(detector.incidents)}"
    )
    ckpt.wait()
    assert losses[-1] < losses[0], "training must descend"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
